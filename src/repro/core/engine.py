"""HR Engine — the shim layer of paper §4, simulated-cluster edition.

Five modules, mapped 1:1 onto the paper's Figure 3:

  Request Agency    → ``HREngine.read`` / ``read_many`` / ``write``
                      (client API)
  Replica Generator → ``create_column_family`` (runs HRCA at CREATE, then
                      places replicas on nodes via hash(replica_id, pk))
  Cost Evaluator    → ``CostModel`` over live ``TableStats``
  Request Scheduler → cheapest-replica routing w/ tie round-robin (load
                      balance) and optional straggler hedging
  Write Scheduler   → commit log → per-replica memtable → flushed sorted
                      runs; each replica sorts through its own LSM-style
                      merge path (Table 1: HR write speed == TR write
                      speed)
  Recovery          → rebuild lost replicas by replaying the shared
                      commit log (default; bit-identical to re-sorting a
                      survivor, which remains available — §4 "leverage
                      the LSM-Tree write process"; §5.4)

Nodes are simulated (this container is one host), but every byte of the
data path is real: tables, scans, sorts and stats are actual arrays, so
rows_scanned/latency numbers in benchmarks are measurements, not models.

Batched reads (``read_many``)
-----------------------------
Production traffic arrives in batches; ``read_many`` amortizes the
scheduler and the storage scan across a whole batch while preserving the
sequential semantics exactly:

* **Cost estimation** is vectorized over all (query, replica) pairs
  (``estimate_rows_many``); the float64 expressions match the scalar
  path bit-for-bit, so the cost matrix equals Q×R scalar calls.
* **Tie-break**: per query (in batch order) the cheapest live replicas
  within the same relative tolerance as ``read`` form the tie set, and
  one round-robin counter draw is consumed per query — a ``read_many``
  over a batch picks exactly the replicas a sequential ``read`` loop
  would.
* **Execution** groups queries by chosen replica and answers each group
  with one ``SortedTable.execute_many`` (single vectorized searchsorted
  over packed slab bounds); per-query results/rows_scanned are identical
  to ``execute``. Group wall time is attributed evenly across the
  group's executed queries (× node slowdown). For a *device-resident*
  column family (``create_column_family(device_resident=True)``) each
  group is answered by one FUSED locate+scan Pallas launch
  (``repro.kernels.table_execute_device_many``): slab location happens
  inside the scan predicate (zero host ``searchsorted`` calls, no host
  sync between locate and scan), the replica's columns stream through
  VMEM once per group regardless of group size, and mixed
  sum/count/select groups share the launch ("select" row indices come
  from a second prefix-sum compaction launch sized by the first's
  int32 match counts). The scalar ``read`` path routes through the
  same kernel at Q = 1, so batched and sequential results stay
  identical; numpy remains the reference engine and the path for host
  tables.
* **Result cache**: each replica keeps a ``(packed slab bounds, agg,
  value col, filters) → ScanResult`` cache shared by both paths,
  invalidated by ``write``/``fail_node``/``recover_node``; hit/miss
  counters live on ``HREngine.stats``.
* **Hedging**: with ``hedge=True``, queries whose chosen node is a
  straggler (slowdown > ``hedge_ratio``) are duplicated — grouped per
  alternate replica (the next-cheapest on a *different* node, as in
  ``read``) — and the faster copy wins per query.

Durable write path (``write``)
------------------------------
Every write runs Cassandra's commit-log → memtable → sorted-run
pipeline (``repro.core.storage``): the batch is appended to the column
family's layout-agnostic :class:`CommitLog` (one shared record stream —
record 0 is the CREATE-time base dataset), staged into each live
replica's :class:`Memtable`, and flushed as an immutable sorted run in
that replica's own key layout via ``SortedTable.merge_run``. With
``memtable_rows > 0`` flushes are deferred until the staging threshold
(group commit: one sort + one merge per group instead of one per
write); reads flush a replica's pending rows before consulting it or
its result cache, so staged-but-unflushed writes can never serve stale
aggregates. On device-resident column families each flush appends a run
to the resident arrays and the :class:`CompactionPolicy` collapses the
run stack on device (Pallas k-way merge, ``merge_device_runs``) once
appended rows outgrow the base — no manual
``place_on_device(rebuild=True)``. Flushes and compactions invalidate
the affected replica's result-cache entries; counters for log records,
staged rows, flushes and compactions ride on :attr:`HREngine.stats`.
"""

from __future__ import annotations

import dataclasses
import itertools
import time
import zlib
from concurrent.futures import ThreadPoolExecutor
from typing import Mapping, Sequence

import numpy as np

from .cost_model import (
    CostModel,
    LinearCostFunction,
    estimate_rows,
    estimate_rows_many,
    precompute_query_stats,
)
from .ecdf import TableStats
from .hrca import HRCAResult, exhaustive_search, hrca, initial_state
from .keys import KeySchema
from .storage import CommitLog, CompactionPolicy, Memtable, compact_table
from .table import ScanResult, SortedTable
from .workload import Query, Workload

__all__ = ["Node", "ReplicaHandle", "ColumnFamily", "ReadReport", "HREngine"]


@dataclasses.dataclass
class Node:
    node_id: int
    alive: bool = True
    slowdown: float = 1.0  # >1 = straggler (ft.straggler injects this)
    tables: dict[tuple[str, int], SortedTable] = dataclasses.field(default_factory=dict)

    def bytes_stored(self) -> int:
        total = 0
        for t in self.tables.values():
            total += t.packed.nbytes
            total += sum(c.nbytes for c in t.key_cols.values())
            total += sum(np.asarray(c).nbytes for c in t.value_cols.values())
        return total


@dataclasses.dataclass
class ReplicaHandle:
    replica_id: int
    layout: tuple[str, ...]
    node_id: int


@dataclasses.dataclass
class ColumnFamily:
    name: str
    schema: KeySchema
    key_names: tuple[str, ...]
    value_names: tuple[str, ...]
    replicas: list[ReplicaHandle]
    stats: TableStats
    cost_model: CostModel
    hrca_result: HRCAResult | None = None
    # replica tables held as device-resident jax arrays: reads route
    # through the batched Pallas scan, and every table produced by the
    # write/recovery paths is re-placed on device
    device_resident: bool = False
    rr_counter: "itertools.count" = dataclasses.field(default_factory=itertools.count)
    # durable write path: shared layout-agnostic commit log (record 0 =
    # CREATE-time base), one memtable per replica, compaction policy for
    # device run stacks, and the group-commit staging threshold (0 =
    # write-through: every write flushes)
    commitlog: CommitLog | None = None
    memtables: dict[int, Memtable] = dataclasses.field(default_factory=dict)
    compaction: CompactionPolicy | None = None
    memtable_rows: int = 0


@dataclasses.dataclass
class ReadReport:
    replica_id: int
    node_id: int
    estimated_rows: float
    estimated_cost: float
    wall_seconds: float  # measured scan time × node slowdown
    rows_scanned: int
    hedged: bool = False


_Ranked = tuple[float, float, ReplicaHandle]  # (est_cost, est_rows, handle)


def _tie_threshold(best_cost: float) -> float:
    """Cost at or under which a replica counts as tied with the best.
    Shared by ``read`` and ``read_many`` — batched/sequential routing
    parity depends on both using the identical float expression. The
    margin is on |cost| so the threshold is ≥ best_cost even when a
    fitted cost function goes negative (negative intercept): the tie
    set always contains the cheapest replica."""
    return best_cost + abs(best_cost) * 1e-9 + 1e-12


class HREngine:
    """Simulated-cluster HR engine (Request Agency facade).

    ``result_cache`` (default on) keeps a per-replica map
    ``(agg, value col, filter signature) → ScanResult`` fed by both
    ``read`` and ``read_many``. The packed slab bounds are a pure
    function of (filters, layout, schema) and each replica has its own
    map, so the filter signature alone identifies the slab — keying on
    it avoids re-running the ``slab_bounds_many`` walk just to build
    keys. Writes and node recovery invalidate the affected replicas'
    entries, each per-replica map is bounded in entries
    (``result_cache_max_entries``, FIFO eviction) and in retained
    select-index bytes, and hit/miss counters are exposed on
    :attr:`stats`.
    """

    def __init__(
        self,
        n_nodes: int = 6,
        *,
        result_cache: bool = True,
        result_cache_max_entries: int = 4096,
        parallel_writes: bool = False,
        memtable_rows: int = 0,
        compaction: CompactionPolicy | None = None,
    ) -> None:
        if n_nodes < 1:
            raise ValueError("need at least one node")
        if result_cache and result_cache_max_entries < 1:
            raise ValueError(
                "result_cache_max_entries must be >= 1; pass "
                "result_cache=False to disable caching"
            )
        if memtable_rows < 0:
            raise ValueError("memtable_rows must be >= 0 (0 = write-through)")
        self.nodes = [Node(node_id=i) for i in range(n_nodes)]
        self.column_families: dict[str, ColumnFamily] = {}
        self._cache_enabled = result_cache
        self._cache_max = result_cache_max_entries
        self._cache_hits = 0
        self._cache_misses = 0
        self._result_cache: dict[tuple[str, int], dict] = {}
        # running total of selected-array bytes per replica map, so the
        # byte budget doesn't rescan the map on every store
        self._cache_sel_bytes: dict[tuple[str, int], int] = {}
        self.parallel_writes = parallel_writes
        # write-path defaults inherited by create_column_family
        self.memtable_rows = memtable_rows
        self.compaction = compaction if compaction is not None else CompactionPolicy()
        self._flushes = 0
        self._compactions = 0
        # cumulative seconds spent in memtable flushes (incl. the ones
        # a read barrier triggers, which are write-path cost and NOT
        # attributed to any ReadReport.wall_seconds)
        self._flush_wall = 0.0
        self._pool: ThreadPoolExecutor | None = None

    @property
    def _executor(self) -> ThreadPoolExecutor:
        """Shared flush thread pool, created lazily on first parallel
        flush — a per-flush pool's startup cost would eat into the
        overlap ``benchmarks/write_queue.py`` measures."""
        if self._pool is None:
            self._pool = ThreadPoolExecutor(max_workers=8)
        return self._pool

    def __getstate__(self) -> dict:
        # thread pools hold locks/threads and cannot be (deep)copied or
        # pickled; drop it — it is recreated lazily on first use
        state = self.__dict__.copy()
        state["_pool"] = None
        return state

    # -- result cache --------------------------------------------------------

    @property
    def stats(self) -> dict:
        """Operational counters: per-replica read result cache plus the
        durable write path (log records/rows, currently staged rows,
        memtable flushes and automatic compactions)."""
        cfs = self.column_families.values()
        return {
            "result_cache_hits": self._cache_hits,
            "result_cache_misses": self._cache_misses,
            "result_cache_entries": sum(
                len(c) for c in self._result_cache.values()
            ),
            "result_cache_select_bytes": sum(self._cache_sel_bytes.values()),
            "commitlog_records": sum(
                len(cf.commitlog) for cf in cfs if cf.commitlog is not None
            ),
            "commitlog_rows": sum(
                cf.commitlog.n_rows for cf in cfs if cf.commitlog is not None
            ),
            "staged_rows": sum(
                mt.n_staged for cf in cfs for mt in cf.memtables.values()
            ),
            "memtable_flushes": self._flushes,
            "compactions": self._compactions,
            # cumulative wall of ALL flushes. Flushes inside write()
            # (write-through or threshold-crossing) also count toward
            # that write's returned wall — don't sum the two. The
            # counter exists because read-barrier flushes appear in
            # neither write()'s return nor any ReadReport.wall_seconds;
            # here is the only place that time is visible
            "flush_wall_seconds": self._flush_wall,
        }

    @staticmethod
    def _cache_keys(queries: list[Query]) -> list:
        """One key per query: aggregation + value column + filter
        signature. The cache is per-replica, so layout (and with it the
        packed slab bounds, a pure function of the filters) is implicit
        — no bounds walk on the hot path just to build keys."""
        return [
            (q.agg, q.value_col, tuple(sorted(q.filters.items())))
            for q in queries
        ]

    # a select's cached index array may be arbitrarily large; entries
    # past the per-entry byte size are served but never cached, and each
    # replica map evicts FIFO until its retained selected-array bytes
    # fit the map budget — so worst-case memory is bounded per replica
    # by min(max_entries × entry cap, map budget), not by table size
    _CACHE_MAX_SELECT_BYTES = 1 << 20
    _CACHE_MAX_MAP_BYTES = 64 << 20

    def _cache_store(self, map_key, cache: dict, key, result: ScanResult) -> None:
        """Cache hits hand out the same ScanResult object, so a select's
        index array is frozen on the way in — a caller mutating it would
        otherwise corrupt every later hit. Each per-replica map is
        bounded in entries (``result_cache_max_entries``, FIFO) and in
        selected-array bytes: workloads of all-distinct (select)
        queries must not grow memory without bound."""
        nb = 0 if result.selected is None else int(result.selected.nbytes)
        if nb > self._CACHE_MAX_SELECT_BYTES:
            return
        if result.selected is not None:
            result.selected.setflags(write=False)
        total = self._cache_sel_bytes.get(map_key, 0)
        old = cache.pop(key, None)
        if old is not None and old.selected is not None:
            total -= old.selected.nbytes
        while cache and (
            len(cache) >= self._cache_max
            or total + nb > self._CACHE_MAX_MAP_BYTES
        ):
            evicted = cache.pop(next(iter(cache)))
            if evicted.selected is not None:
                total -= evicted.selected.nbytes
        cache[key] = result
        self._cache_sel_bytes[map_key] = total + nb

    def _invalidate_result_cache(
        self,
        cf_name: str,
        node_id: int | None = None,
        replica_id: int | None = None,
    ) -> None:
        cf = self.column_families[cf_name]
        for r in cf.replicas:
            if node_id is not None and r.node_id != node_id:
                continue
            if replica_id is not None and r.replica_id != replica_id:
                continue
            self._result_cache.pop((cf_name, r.replica_id), None)
            self._cache_sel_bytes.pop((cf_name, r.replica_id), None)

    # -- Replica Generator ---------------------------------------------------

    def _place(self, replica_id: int, cf_name: str) -> int:
        """Replica placement hash(replica_id, cf) → node. Successive
        replicas land on distinct nodes when possible (Cassandra ring).

        Uses crc32, not ``hash``: the builtin is salted per process
        (PYTHONHASHSEED), which made placement — and every benchmark
        downstream of it — differ between runs.
        """
        h = zlib.crc32(cf_name.encode("utf-8")) % len(self.nodes)
        return (h + replica_id) % len(self.nodes)

    def create_column_family(
        self,
        name: str,
        key_cols: Mapping[str, np.ndarray],
        value_cols: Mapping[str, np.ndarray],
        *,
        replication_factor: int = 3,
        mechanism: str = "HR",
        workload: Workload | None = None,
        schema: KeySchema | None = None,
        cost_fns: dict[int, LinearCostFunction] | None = None,
        hrca_kwargs: dict | None = None,
        layouts: Sequence[Sequence[str]] | None = None,
        device_resident: bool = False,
        memtable_rows: int | None = None,
        compaction: CompactionPolicy | None = None,
    ) -> ColumnFamily:
        """CREATE COLUMN FAMILY: choose replica structures, build tables.

        mechanism:
          "HR" — layouts from HRCA over ``workload`` (paper).
          "TR" — the single best expert layout, identical on all replicas
                 (the paper's baseline: "approximate optimal structure
                 that an expert can give"); exhaustive for ≤5 keys, else
                 single-replica annealing + greedy polish.
        Explicit ``layouts`` override both (tests / ablations).

        With ``device_resident=True`` every replica table is placed on
        device at creation: ``read``/``read_many`` then answer sum,
        count and select queries with the fused locate+scan Pallas
        launch instead of the numpy engine, writes *append* to the
        resident arrays (incremental placement — no re-upload), and
        recovery re-places rebuilt tables. Raises if the schema exceeds
        the device path's per-column two-lane budget.

        ``memtable_rows`` (default: the engine's) is the group-commit
        staging threshold — 0 means write-through, every ``write``
        flushes. ``compaction`` (default: the engine's policy) bounds
        the device run stack; pass an explicit ``CompactionPolicy`` to
        tune its thresholds. The CREATE-time dataset is committed as
        record 0 of the column family's shared commit log, so replaying
        the log alone rebuilds any replica.
        """
        if name in self.column_families:
            raise ValueError(f"column family {name!r} exists")
        if schema is None:
            schema = KeySchema.for_columns(key_cols)
        key_names = tuple(key_cols)
        stats = TableStats.from_columns(key_cols, schema)
        model = CostModel(stats=stats, cost_fns=dict(cost_fns or {}))
        n = replication_factor
        hrca_result: HRCAResult | None = None

        if layouts is not None:
            chosen = tuple(tuple(a) for a in layouts)
            if len(chosen) != n:
                raise ValueError("len(layouts) != replication_factor")
        elif mechanism == "TR":
            if workload is None:
                chosen = tuple(tuple(key_names) for _ in range(n))
            else:
                best = self._expert_layout(model, workload, key_names)
                chosen = tuple(best for _ in range(n))
        elif mechanism == "HR":
            if workload is None:
                raise ValueError("HR mechanism needs a workload for HRCA")
            kw = dict(hrca_kwargs or {})
            hrca_result = hrca(model, workload, initial_state(key_names, n), **kw)
            chosen = hrca_result.layouts
        else:
            raise ValueError(f"unknown mechanism {mechanism!r}")

        value_names = tuple(value_cols)
        replicas = []
        memtables: dict[int, Memtable] = {}
        for rid, layout in enumerate(chosen):
            table = SortedTable.from_columns(key_cols, value_cols, layout, schema)
            if device_resident:
                table.place_on_device()
            node_id = self._place(rid, name)
            self.nodes[node_id].tables[(name, rid)] = table
            replicas.append(ReplicaHandle(rid, tuple(layout), node_id))
            memtables[rid] = Memtable(layout, schema, key_names, value_names)

        log = CommitLog(key_names=key_names, value_names=value_names)
        log.append(key_cols, value_cols)  # record 0: the base dataset

        cf = ColumnFamily(
            name=name,
            schema=schema,
            key_names=key_names,
            value_names=value_names,
            replicas=replicas,
            stats=stats,
            cost_model=model,
            hrca_result=hrca_result,
            device_resident=device_resident,
            commitlog=log,
            memtables=memtables,
            compaction=compaction if compaction is not None else self.compaction,
            memtable_rows=(
                self.memtable_rows if memtable_rows is None else memtable_rows
            ),
        )
        self.column_families[name] = cf
        return cf

    @staticmethod
    def _expert_layout(
        model: CostModel, workload: Workload, key_names: tuple[str, ...]
    ) -> tuple[str, ...]:
        if len(key_names) <= 5:
            state, _ = exhaustive_search(model, workload, key_names, 1)
            return state[0]
        res = hrca(
            model, workload, initial_state(key_names, 1), greedy_descent=True, k_max=2000
        )
        return res.layouts[0]

    # -- Cost Evaluator / Request Scheduler -----------------------------------

    def _table(self, cf: ColumnFamily, r: ReplicaHandle) -> SortedTable:
        return self.nodes[r.node_id].tables[(cf.name, r.replica_id)]

    def _ranked_replicas(self, cf: ColumnFamily, query: Query) -> list[_Ranked]:
        """Replicas on live nodes ranked by estimated cost (Eq 2–3)."""
        ranked: list[_Ranked] = []
        for r in cf.replicas:
            if not self.nodes[r.node_id].alive:
                continue
            rows = estimate_rows(cf.stats, r.layout, query)
            ranked.append((cf.cost_model.cost_fn(len(r.layout))(rows), rows, r))
        if not ranked:
            raise RuntimeError(f"no live replica for {cf.name!r}")
        ranked.sort(key=lambda t: t[0])
        return ranked

    def _execute_on(
        self, cf: ColumnFamily, entry: _Ranked, query: Query, hedged: bool
    ) -> tuple[ScanResult, ReadReport]:
        est_cost, est_rows, r = entry
        # staged-but-unflushed writes must be visible (and must not let
        # a stale cache entry answer): flush before the cache lookup
        self._ensure_flushed(cf, r)
        table = self._table(cf, r)
        cache = ckey = None
        if self._cache_enabled:
            cache = self._result_cache.setdefault((cf.name, r.replica_id), {})
            (ckey,) = self._cache_keys([query])
        t0 = time.perf_counter()
        if cache is not None and ckey in cache:
            result = cache[ckey]
            self._cache_hits += 1
        else:
            result = table.execute(query)
            if cache is not None:
                self._cache_store((cf.name, r.replica_id), cache, ckey, result)
                self._cache_misses += 1
        wall = (time.perf_counter() - t0) * self.nodes[r.node_id].slowdown
        report = ReadReport(
            replica_id=r.replica_id,
            node_id=r.node_id,
            estimated_rows=est_rows,
            estimated_cost=est_cost,
            wall_seconds=wall,
            rows_scanned=result.rows_scanned,
            hedged=hedged,
        )
        return result, report

    def read(
        self, cf_name: str, query: Query, *, hedge: bool = False, hedge_ratio: float = 2.0
    ) -> tuple[ScanResult, ReadReport]:
        """Route to the cheapest live replica; ties broken round-robin
        (load balance). With ``hedge=True`` a read landing on a straggler
        node (slowdown > hedge_ratio) is duplicated on the next-cheapest
        replica on a *different* node; the faster copy wins.
        """
        cf = self.column_families[cf_name]
        ranked = self._ranked_replicas(cf, query)
        best_cost = ranked[0][0]
        ties = [t for t in ranked if t[0] <= _tie_threshold(best_cost)]
        pick = ties[next(cf.rr_counter) % len(ties)]

        result, report = self._execute_on(cf, pick, query, hedged=False)
        if hedge and len(ranked) > 1 and self.nodes[pick[2].node_id].slowdown > hedge_ratio:
            alt = next(
                (t for t in ranked if t[2].node_id != pick[2].node_id), None
            )
            if alt is not None:
                r2, rep2 = self._execute_on(cf, alt, query, hedged=True)
                if rep2.wall_seconds < report.wall_seconds:
                    return r2, rep2
        return result, report

    def read_many(
        self,
        cf_name: str,
        queries: Sequence[Query],
        *,
        hedge: bool = False,
        hedge_ratio: float = 2.0,
    ) -> list[tuple[ScanResult, ReadReport]]:
        """Batched ``read``: one scheduler pass and one grouped storage
        scan for the whole batch (see module docstring for semantics).

        Returns per-query ``(ScanResult, ReadReport)`` in batch order;
        results and routing decisions are identical to calling ``read``
        on each query in sequence.
        """
        cf = self.column_families[cf_name]
        queries = list(queries)
        if not queries:
            return []
        live = [r for r in cf.replicas if self.nodes[r.node_id].alive]
        if not live:
            raise RuntimeError(f"no live replica for {cf_name!r}")
        n_q = len(queries)

        # vectorized Cost Evaluator: Eq (1)-(2) over all (replica, query);
        # per-column selectivities are extracted once and shared by all
        # replica layouts
        pre = precompute_query_stats(cf.stats, queries, cf.key_names)
        rows_mat = np.stack(
            [estimate_rows_many(cf.stats, r.layout, queries, pre) for r in live]
        )
        cost_mat = np.stack(
            [
                cf.cost_model.cost_fn(len(r.layout)).many(rows_mat[k])
                for k, r in enumerate(live)
            ]
        )

        # Request Scheduler: per-query cheapest replica, RR tie-break.
        # Sorted ascending, the within-tolerance ties are exactly the
        # first tie_count entries of each column's stable order — the
        # same tie list ``read`` builds. One rr_counter draw per query,
        # in batch order, so a batch matches a sequential read loop.
        order_mat = np.argsort(cost_mat, axis=0, kind="stable")  # (R, Q)
        sorted_costs = np.take_along_axis(cost_mat, order_mat, axis=0)
        thresh = _tie_threshold(sorted_costs[0])  # elementwise over queries
        tie_counts = (sorted_costs <= thresh[None, :]).sum(axis=0)
        draws = np.fromiter(
            (next(cf.rr_counter) for _ in range(n_q)), dtype=np.int64, count=n_q
        )
        picks = order_mat[draws % tie_counts, np.arange(n_q)]

        # group queries by chosen replica; one batched scan per group
        groups: dict[int, list[int]] = {}
        for qi in range(n_q):
            groups.setdefault(int(picks[qi]), []).append(qi)
        results: list[ScanResult | None] = [None] * n_q
        reports: list[ReadReport | None] = [None] * n_q
        for k, qidx in groups.items():
            self._execute_group(
                cf, live[k], qidx, queries, rows_mat[k], cost_mat[k],
                results, reports, hedged=False,
            )

        if hedge and len(live) > 1:
            # duplicate straggler-bound queries onto the next-cheapest
            # replica on a different node (same alternate ``read`` picks)
            hedge_groups: dict[int, list[int]] = {}
            for qi in range(n_q):
                pick_node = live[int(picks[qi])].node_id
                if self.nodes[pick_node].slowdown <= hedge_ratio:
                    continue
                alt = next(
                    (
                        int(k)
                        for k in order_mat[:, qi]
                        if live[int(k)].node_id != pick_node
                    ),
                    -1,
                )
                if alt >= 0:
                    hedge_groups.setdefault(alt, []).append(qi)
            for k, qidx in hedge_groups.items():
                self._execute_group(
                    cf, live[k], qidx, queries, rows_mat[k], cost_mat[k],
                    results, reports, hedged=True,
                )

        return list(zip(results, reports))  # type: ignore[arg-type]

    def _execute_group(
        self,
        cf: ColumnFamily,
        r: ReplicaHandle,
        qidx: list[int],
        queries: list[Query],
        est_rows: np.ndarray,
        est_costs: np.ndarray,
        results: list,
        reports: list,
        *,
        hedged: bool,
    ) -> None:
        """Run one replica's query group via ``execute_many``; measured
        wall time (× node slowdown) is split evenly across the queries
        that actually executed — result-cache hits are served at zero
        attributed wall. Hedged runs only replace a query's primary
        result when faster."""
        self._ensure_flushed(cf, r)  # pending writes first (see _execute_on)
        table = self._table(cf, r)
        group = [queries[i] for i in qidx]
        cache = ckeys = None
        if self._cache_enabled:
            cache = self._result_cache.setdefault((cf.name, r.replica_id), {})
            ckeys = self._cache_keys(group)
        hit_j = set() if cache is None else {j for j, k in enumerate(ckeys) if k in cache}
        miss_j = [j for j in range(len(group)) if j not in hit_j]
        t0 = time.perf_counter()
        miss_scans = table.execute_many([group[j] for j in miss_j]) if miss_j else []
        wall = (time.perf_counter() - t0) * self.nodes[r.node_id].slowdown
        per_q_wall = wall / len(miss_j) if miss_j else 0.0
        scans: list[ScanResult | None] = [None] * len(group)
        walls = [0.0] * len(group)
        # read the hits out BEFORE storing misses: a store can FIFO-evict
        # a key that was a hit when hit_j was computed
        for j in hit_j:
            scans[j] = cache[ckeys[j]]
        for j, sr in zip(miss_j, miss_scans):
            scans[j] = sr
            walls[j] = per_q_wall
            if cache is not None:
                self._cache_store((cf.name, r.replica_id), cache, ckeys[j], sr)
        if cache is not None:
            self._cache_hits += len(hit_j)
            self._cache_misses += len(miss_j)
        for j, i in enumerate(qidx):
            sr = scans[j]
            if hedged and not (
                reports[i] is None or walls[j] < reports[i].wall_seconds
            ):
                continue
            results[i] = sr
            reports[i] = ReadReport(
                replica_id=r.replica_id,
                node_id=r.node_id,
                estimated_rows=float(est_rows[i]),
                estimated_cost=float(est_costs[i]),
                wall_seconds=walls[j],
                rows_scanned=sr.rows_scanned,
                hedged=hedged,
            )

    # -- Write Scheduler (commit log → memtable → sorted runs) ----------------

    def write(
        self,
        cf_name: str,
        key_cols: Mapping[str, np.ndarray],
        value_cols: Mapping[str, np.ndarray],
        *,
        parallel: bool | None = None,
        flush: bool | None = None,
    ) -> float:
        """Commit a batch write through the durable path and refresh
        stats; returns wall seconds. The batch is (1) appended to the
        column family's shared commit log — the layout-agnostic
        durability record any replica can be rebuilt from — then (2)
        staged into each live replica's memtable, and (3) flushed as one
        sorted run per replica when the staging threshold is reached
        (``memtable_rows``; 0 = write-through, so every write flushes).
        ``flush`` forces (True) or defers (False) step 3 explicitly.
        Matches §5.3: per-replica flush cost is one sort regardless of
        layout, so HR writes cost the same as TR (Table 1).

        *Group commit falls out of the staging*: with a threshold set, g
        writes of b rows flush as one sort + one merge of g×b rows —
        the amortization ``benchmarks/write_queue.py`` measures. The
        per-replica flushes remain independent and ``parallel=True``
        (default: the engine's ``parallel_writes`` flag) overlaps them
        on a thread pool; the merge hot path now runs through
        GIL-releasing ``np.sort`` + scatters (``SortedTable.merge_run``),
        and the same benchmark re-measures the overlap honestly.

        Deferred rows are never stale-served: reads flush a replica's
        pending rows (invalidating its cached results) before touching
        it. On a device-resident column family each flush *appends* its
        run to the replica's resident arrays and the column family's
        ``CompactionPolicy`` collapses the run stack on device once it
        outgrows the base — nothing is re-uploaded either way.
        """
        cf = self.column_families[cf_name]
        if parallel is None:
            parallel = self.parallel_writes
        t0 = time.perf_counter()
        cf.commitlog.append(key_cols, value_cols)
        rec = cf.commitlog.tail
        # missed writes on dead nodes are repaired by Recovery (the log
        # has every record; dead replicas neither stage nor flush). The
        # record's columns are the log's own immutable copies, so every
        # memtable stages them by reference — one copy per write, not RF
        live = [r for r in cf.replicas if self.nodes[r.node_id].alive]
        for r in live:
            cf.memtables[r.replica_id].stage(
                rec.key_cols, rec.value_cols, copy=False
            )
        cf.stats.merge_rows(key_cols, device=cf.device_resident)
        if flush is None:
            flush = cf.memtable_rows <= 0 or any(
                cf.memtables[r.replica_id].n_staged >= cf.memtable_rows
                for r in live
            )
        if flush:
            self._flush_replicas(cf, live, parallel=parallel)
        return time.perf_counter() - t0

    def _flush_replicas(
        self, cf: ColumnFamily, replicas: Sequence[ReplicaHandle], *, parallel: bool = False
    ) -> None:
        """Flush the given replicas' staged rows: one sorted run per
        replica (in its own layout), merged via ``merge_run``, result
        cache invalidated, then the compaction policy applied to the
        merged table. ``parallel`` overlaps the independent per-replica
        merges on a thread pool."""
        pending = [
            r
            for r in replicas
            if self.nodes[r.node_id].alive and cf.memtables[r.replica_id].n_staged
        ]
        if not pending:
            return
        t0 = time.perf_counter()

        def _flush(r: ReplicaHandle) -> tuple[ReplicaHandle, SortedTable]:
            # peek, don't drain: the memtable is cleared only after the
            # merged table is installed below, so an exception here (or
            # in a sibling thread) never loses committed rows — the
            # staged buffers and the old table both survive a retry
            run = cf.memtables[r.replica_id].peek_run()
            table = self.nodes[r.node_id].tables[(cf.name, r.replica_id)]
            return r, table.merge_run(run)

        if parallel and len(pending) > 1:
            merged_tables = list(self._executor.map(_flush, pending))
        else:
            merged_tables = [_flush(r) for r in pending]
        for r, merged in merged_tables:
            if cf.device_resident and not merged.device_resident:
                merged.place_on_device()
            self.nodes[r.node_id].tables[(cf.name, r.replica_id)] = merged
            cf.memtables[r.replica_id].clear()
            self._flushes += 1
            self._invalidate_result_cache(cf.name, replica_id=r.replica_id)
            if cf.compaction is not None and compact_table(merged, cf.compaction):
                self._compactions += 1
                self._invalidate_result_cache(cf.name, replica_id=r.replica_id)
        self._flush_wall += time.perf_counter() - t0

    def _ensure_flushed(self, cf: ColumnFamily, r: ReplicaHandle) -> None:
        """Flush one replica's pending staged rows (read barrier)."""
        mt = cf.memtables.get(r.replica_id)
        if mt is not None and mt.n_staged:
            self._flush_replicas(cf, [r])

    def flush_memtables(self, cf_name: str, *, parallel: bool | None = None) -> None:
        """Drain every live replica's memtable (group-commit flush)."""
        cf = self.column_families[cf_name]
        if parallel is None:
            parallel = self.parallel_writes
        live = [r for r in cf.replicas if self.nodes[r.node_id].alive]
        self._flush_replicas(cf, live, parallel=parallel)

    def checkpoint_commitlog(self, cf_name: str) -> int:
        """Collapse the column family's commit log into one snapshot
        record, bounding log memory and replay-recovery cost at
        O(current rows) instead of O(rows ever written). Flushes every
        live replica first so no record still backs staged-only rows;
        log-replay recovery is unchanged (the snapshot replays to the
        identical dataset). Returns the snapshot's LSN."""
        cf = self.column_families[cf_name]
        self.flush_memtables(cf_name)
        return cf.commitlog.checkpoint()

    # -- Recovery ----------------------------------------------------------------

    def fail_node(self, node_id: int) -> None:
        node = self.nodes[node_id]
        node.alive = False
        node.tables = {}  # disk lost
        for cf_name, cf in self.column_families.items():
            for r in cf.replicas:
                if r.node_id == node_id and r.replica_id in cf.memtables:
                    # the memtable dies with its node; the commit log is
                    # the durable copy every staged row replays from
                    cf.memtables[r.replica_id].clear()
            self._invalidate_result_cache(cf_name, node_id=node_id)

    def recover_node(self, node_id: int, *, source: str = "log") -> float:
        """Rebuild every replica the node hosted, in that replica's own
        heterogeneous layout. Returns wall seconds (§5.4 bench).

        ``source="log"`` (default) replays the column family's shared
        commit log: the layout-agnostic record stream — base dataset
        plus every committed write, including ones the dead node missed
        and rows that were staged-but-unflushed anywhere when the node
        died — is sorted into the lost replica's layout. The result is
        the same dataset and serialization the surviving-peer path
        produces (bit-identical packed keys and key columns; value
        columns too whenever composite keys are unique — the tie order
        among duplicate full keys is the only degree of freedom).

        ``source="survivor"`` keeps the original path: stream a
        surviving replica of the same column family and re-sort it
        (same dataset, different serialization). It is also the
        fallback for column families without a commit log.
        """
        if source not in ("log", "survivor"):
            raise ValueError(f"unknown recovery source {source!r}")
        node = self.nodes[node_id]
        t0 = time.perf_counter()
        node.alive = True
        for cf_name in self.column_families:
            self._invalidate_result_cache(cf_name, node_id=node_id)
        for cf in self.column_families.values():
            for r in cf.replicas:
                if r.node_id != node_id:
                    continue
                if source == "log" and cf.commitlog is not None and len(cf.commitlog):
                    kc, vc = cf.commitlog.replay_columns()
                    rebuilt = SortedTable.from_columns(kc, vc, r.layout, cf.schema)
                else:
                    survivor = next(
                        (
                            s
                            for s in cf.replicas
                            if s.replica_id != r.replica_id
                            and self.nodes[s.node_id].alive
                            and (cf.name, s.replica_id) in self.nodes[s.node_id].tables
                        ),
                        None,
                    )
                    if survivor is None:
                        raise RuntimeError(
                            f"data loss: no survivor for {cf.name!r} "
                            f"replica {r.replica_id}"
                        )
                    self._ensure_flushed(cf, survivor)  # staged rows too
                    src = self.nodes[survivor.node_id].tables[
                        (cf.name, survivor.replica_id)
                    ]
                    rebuilt = src.resorted(r.layout)
                if cf.device_resident:
                    rebuilt.place_on_device()
                node.tables[(cf.name, r.replica_id)] = rebuilt
                # fresh memtable: a log rebuild is fully flushed state
                cf.memtables[r.replica_id] = Memtable(
                    r.layout, cf.schema, cf.key_names, cf.value_names
                )
        return time.perf_counter() - t0

    # -- introspection -------------------------------------------------------------

    def layouts(self, cf_name: str) -> tuple[tuple[str, ...], ...]:
        return tuple(r.layout for r in self.column_families[cf_name].replicas)

    def total_bytes(self) -> int:
        return sum(n.bytes_stored() for n in self.nodes)
