"""HR Engine — the shim layer of paper §4, simulated-cluster edition.

Five modules, mapped 1:1 onto the paper's Figure 3:

  Request Agency    → ``HREngine.read`` / ``read_many`` / ``write``
                      (client API)
  Replica Generator → ``create_column_family`` (runs HRCA at CREATE, then
                      places replicas on nodes via hash(replica_id, pk))
  Cost Evaluator    → ``CostModel`` over live ``TableStats``
  Request Scheduler → cheapest-replica routing w/ tie round-robin (load
                      balance) and optional straggler hedging
  Write Scheduler   → commit log → per-replica memtable → flushed sorted
                      runs; each replica sorts through its own LSM-style
                      merge path (Table 1: HR write speed == TR write
                      speed)
  Recovery          → rebuild lost replicas by replaying the shared
                      commit log (default; bit-identical to re-sorting a
                      survivor, which remains available — §4 "leverage
                      the LSM-Tree write process"; §5.4)

Nodes are simulated (this container is one host), but every byte of the
data path is real: tables, scans, sorts and stats are actual arrays, so
rows_scanned/latency numbers in benchmarks are measurements, not models.

Token-ring partitioning (``create_column_family(partitions=P)``)
----------------------------------------------------------------
A production keyspace is split the way Cassandra's ring splits it
(``repro.core.ring``): rows map to one of ``P`` contiguous token ranges
of the canonical packed key space, and each partition owns a full
heterogeneous replica set of just its rows, its own commit log,
memtables and compaction policy. ``write`` routes rows to the owning
partitions' logs; ``read_many`` scatters each query to the partitions
its slab bounds intersect (pure host arithmetic against the ring's
start tokens), executes per ``(partition, replica)`` group — device
partitions via the fused Pallas launch — and gathers partial
aggregates (sum/count add up; select indices concatenate into the
global "partitions in ring order" index space). ``fail_node`` loses
only the partition replicas the node hosted and ``recover_node``
rebuilds each from its own partition log. ``P = 1`` (the default) is
bit-identical to the unpartitioned engine — same placement, same
routing draws, same results.

Batched reads (``read_many``)
-----------------------------
Production traffic arrives in batches; ``read_many`` amortizes the
scheduler and the storage scan across a whole batch while preserving the
sequential semantics exactly:

* **Cost estimation** is vectorized over all (query, replica) pairs
  (``estimate_rows_many``); the float64 expressions match the scalar
  path bit-for-bit, so the cost matrix equals Q×R scalar calls.
* **Tie-break**: per query (in batch order) the cheapest live replicas
  within the same relative tolerance as ``read`` form the tie set, and
  one round-robin counter draw is consumed per query — a ``read_many``
  over a batch picks exactly the replicas a sequential ``read`` loop
  would.
* **Execution** groups queries by chosen replica and answers each group
  with one ``SortedTable.execute_many`` (single vectorized searchsorted
  over packed slab bounds); per-query results/rows_scanned are identical
  to ``execute``. Group wall time is attributed evenly across the
  group's executed queries (× node slowdown). For a *device-resident*
  column family (``create_column_family(device_resident=True)``) each
  group is answered by one FUSED locate+scan Pallas launch
  (``repro.kernels.table_execute_device_many``): slab location happens
  inside the scan predicate (zero host ``searchsorted`` calls, no host
  sync between locate and scan), the replica's columns stream through
  VMEM once per group regardless of group size, and mixed
  sum/count/select groups share the launch ("select" row indices come
  from a second prefix-sum compaction launch sized by the first's
  int32 match counts). The scalar ``read`` path routes through the
  same kernel at Q = 1, so batched and sequential results stay
  identical; numpy remains the reference engine and the path for host
  tables.
* **Result cache**: each replica keeps a ``(packed slab bounds, agg,
  value col, filters) → ScanResult`` cache shared by both paths,
  invalidated by ``write``/``fail_node``/``recover_node``; hit/miss
  counters live on ``HREngine.stats``.
* **Hedging**: with ``hedge=True``, queries whose chosen node is a
  straggler (slowdown > ``hedge_ratio``) are duplicated — grouped per
  alternate replica (the next-cheapest on a *different* node, as in
  ``read``) — and the faster copy wins per query.

Durable write path (``write``)
------------------------------
Every write runs Cassandra's commit-log → memtable → sorted-run
pipeline (``repro.core.storage``): the batch is appended to the column
family's layout-agnostic :class:`CommitLog` (one shared record stream —
record 0 is the CREATE-time base dataset), staged into each live
replica's :class:`Memtable`, and flushed as an immutable sorted run in
that replica's own key layout via ``SortedTable.merge_run``. With
``memtable_rows > 0`` flushes are deferred until the staging threshold
(group commit: one sort + one merge per group instead of one per
write); reads flush a replica's pending rows before consulting it or
its result cache, so staged-but-unflushed writes can never serve stale
aggregates. On device-resident column families each flush appends a run
to the resident arrays and the :class:`CompactionPolicy` collapses the
run stack on device (Pallas k-way merge, ``merge_device_runs``) once
appended rows outgrow the base — no manual
``place_on_device(rebuild=True)``. Flushes and compactions invalidate
the affected replica's result-cache entries; counters for log records,
staged rows, flushes and compactions ride on :attr:`HREngine.stats`.

Availability layer (hints · consistency · detection · scrub)
------------------------------------------------------------
Cassandra's availability machinery, fitted to the simulated cluster:

* **Hinted handoff** — ``fail_node(node, transient=True)`` models an
  outage that loses memory but not disk: the node's tables survive and
  every hosted partition replica opens a *hint*, the LSN watermark its
  table was flushed through (``Partition.hints``/``flushed_lsn`` — an
  LSN range against the partition's own commit log, never a data
  copy). ``node_up`` then replays only ``[watermark, next_lsn)`` and
  merges that tail into the surviving table — healing a short outage
  costs O(missed writes), not O(dataset) — falling back to a full
  rebuild whenever the tail is gone (a checkpoint collapsed it, or the
  loss was durable). ``recover_node`` keeps the full-rebuild semantics
  for durable losses.
* **Tunable read consistency** — ``read``/``read_many`` accept
  ``consistency="ONE" | "QUORUM" | "ALL"``. Beyond ONE, each query
  also executes on the next cost-ranked replicas up to k (RF//2 + 1
  for QUORUM, RF for ALL) and the k results' *digests* are compared —
  crc32 over the canonical (layout-independent) ``ScanResult``
  encoding: the aggregate value (float32-quantized for sums, whose
  float64 totals differ across layouts only by summation-order noise
  far below one float32 ulp), the matched-row count, and for selects
  the sorted canonical packed keys of the selected rows. A mismatch
  (``digest_mismatches``) triggers **read repair**: minority replicas
  are rebuilt from the partition log — the ground truth — and the
  majority answer is returned (``read_repairs``); with no majority
  every consulted replica is rebuilt and the query re-executes.
* **Failure detection + graceful degradation** — pass
  ``failure_detector=FailureDetector()`` (``repro.ft.detector``; any
  object with ``record``/``record_failure``/``cost_factor`` works) and
  every executed replica-group scan feeds it. Nodes whose phi crosses
  the suspect threshold get their ranking costs *multiplied* by the
  detector's cost factor — soft avoidance, Cassandra's dynamic-snitch
  badness rule, never hard exclusion. When a scan raises (an injected
  fault: ``Node.read_fault_budget``), the planner retries the affected
  queries on the next-ranked untried replica (bounded by the replica
  count, ``read_retries``), recording the failure with the detector.
* **Checksums + scrub** — flushed runs carry crc32 (verified before
  merging) and the engine seals a content crc32 on every table it
  installs; ``scrub_column_family`` re-verifies every live replica and
  heals corrupt ones from the partition log (``scrub_repairs``).

``ft/chaos.py`` drives all of it: a seeded schedule of crash /
torn-log-tail / run-corruption / slow-node / flush-abort events whose
acceptance property is that after detector-driven repair, reads are
row-identical to a no-fault oracle engine fed the same writes.
"""

from __future__ import annotations

import dataclasses
import itertools
import time
import zlib
from concurrent.futures import ThreadPoolExecutor
from typing import Mapping, Sequence

import numpy as np

from ..obs import MetricsRegistry
from .cost_model import (
    CostModel,
    LinearCostFunction,
    estimate_rows,
    estimate_rows_many,
    precompute_query_stats,
)
from .ecdf import TableStats
from .hrca import HRCAResult, exhaustive_search, hrca, initial_state
from .keys import KeySchema, pack_columns
from .ring import Partition, ReplicaHandle, TokenHistogram, TokenRing, place_replica
from .storage import CommitLog, CompactionPolicy, Memtable, compact_table
from .storage.memtable import combine_digests, sort_run
from .storage.views import (
    VIEW_AGGS,
    VIEW_ROWS_CAP,
    query_view_eligible,
    verify_views,
    view_eligible_matrix,
)
from .table import ScanResult, SortedTable, merge_partial_scans, slab_bounds_many
from .workload import Query, Workload

__all__ = [
    "Node",
    "ReplicaHandle",
    "ColumnFamily",
    "ReadReport",
    "HREngine",
    "ONE",
    "QUORUM",
    "ALL",
    "CONSISTENCY_LEVELS",
    "TransientFault",
    "TransientReadError",
    "TransientFlushError",
    "CorruptRunError",
    "DeadlineExceeded",
    "ENGINE_COUNTERS",
    "FAULT_COUNTERS",
    "REPAIR_COUNTERS",
    "VIEW_COUNTERS",
]

#: Tunable read consistency levels (Cassandra's CL, read side): how
#: many cost-ranked replicas must answer — and digest-agree — before a
#: result is returned. ONE trusts the single cheapest replica (the
#: historical behavior and the default).
ONE = "ONE"
QUORUM = "QUORUM"
ALL = "ALL"
CONSISTENCY_LEVELS = (ONE, QUORUM, ALL)


class TransientFault(RuntimeError):
    """A scan or flush raised in a retryable way (injected fault / chaos
    event). Carries the faulting node id; the read planner fails over
    to the next-ranked replica, writers retry the flush."""

    def __init__(self, node_id: int, what: str) -> None:
        super().__init__(f"transient {what} fault on node {node_id}")
        self.node_id = node_id


class TransientReadError(TransientFault):
    def __init__(self, node_id: int) -> None:
        super().__init__(node_id, "read")


class TransientFlushError(TransientFault):
    def __init__(self, node_id: int) -> None:
        super().__init__(node_id, "flush")


class CorruptRunError(RuntimeError):
    """A flushed run failed its crc32 verification before merging."""


class DeadlineExceeded(RuntimeError):
    """A read's latency budget (``deadline_s``) was spent before the
    answer was complete. Raised instead of continuing to scan, retry or
    digest-read past the budget: a request that cannot answer in time
    is shed *explicitly*, never served silently slow. Deliberately not
    a :class:`TransientFault` — failover must not swallow it."""

    def __init__(self, budget_s: float | None = None) -> None:
        what = (
            "read deadline budget spent before the answer completed"
            if budget_s is None
            else f"read deadline budget of {budget_s * 1e3:.3f} ms spent "
            "before the answer completed"
        )
        super().__init__(what)
        self.budget_s = budget_s


#: Registry counters every engine registers at construction, in the
#: names the ``stats`` dict view exposes them under. The counter-
#: coverage audit (tests/test_obs.py) walks this inventory against
#: ``HREngine.metrics.catalog()`` — a counter added to the engine but
#: not listed here (or vice versa) fails the audit.
ENGINE_COUNTERS = (
    "result_cache_hits",
    "result_cache_misses",
    "commitlog_auto_checkpoints",
    "memtable_flushes",
    "compactions",
    "partition_splits",
    "partition_merges",
    "rebalance_rows_moved",
    "empty_partition_skips",
    "hints_queued",
    "hint_replays",
    "hint_rows_replayed",
    "hint_fallbacks",
    "digest_mismatches",
    "read_repairs",
    "read_retries",
    "scrub_checks",
    "scrub_repairs",
    "deadline_exceeded",
    "read_faults",
    "flush_faults",
    "corrupt_runs",
    "flush_wall_seconds",
    "view_hits",
    "view_boundary_rows",
    "view_rebuilds",
)

#: Typed refusal/fault → the registry counter that records it. Every
#: exception type the engine raises (or survives via failover) must
#: appear here; the audit test raises each one and asserts its counter
#: moved.
FAULT_COUNTERS: dict[str, str] = {
    "DeadlineExceeded": "deadline_exceeded",
    "TransientReadError": "read_faults",
    "TransientFlushError": "flush_faults",
    "CorruptRunError": "corrupt_runs",
}

#: Repair paths named by the PR-9 audit satellite: each must increment
#: its registry counter whenever the path runs.
REPAIR_COUNTERS = (
    "hint_replays",
    "hint_fallbacks",
    "read_repairs",
    "scrub_repairs",
)

#: Materialized per-slab aggregate views (PR-10): queries the view path
#: answered, window-edge rows its boundary rescan touched, and full
#: partial rebuilds (create excluded; compaction / migration / recovery
#: / scrub heal included). Audited like the repair inventory — each
#: name must resolve in the registry catalog and move when its path
#: runs.
VIEW_COUNTERS = (
    "view_hits",
    "view_boundary_rows",
    "view_rebuilds",
)


def _deadline_at(deadline_s: float | None) -> float | None:
    """Absolute ``perf_counter`` cutoff for a per-call latency budget
    (None = unbounded). A zero/negative budget yields an already-spent
    cutoff, so the caller sheds before doing any work."""
    if deadline_s is None:
        return None
    return time.perf_counter() + deadline_s


def _deadline_spent(deadline_at: float | None) -> bool:
    return deadline_at is not None and time.perf_counter() >= deadline_at


@dataclasses.dataclass
class Node:
    node_id: int
    alive: bool = True
    slowdown: float = 1.0  # >1 = straggler (ft.straggler injects this)
    tables: dict[tuple[str, int], SortedTable] = dataclasses.field(default_factory=dict)
    # injected fault budgets (chaos harness): each >0 count makes the
    # next scan / flush on this node raise a TransientFault, modeling a
    # slow-failing or flapping node rather than a clean death
    read_fault_budget: int = 0
    flush_fault_budget: int = 0

    def bytes_stored(self) -> int:
        total = 0
        for t in self.tables.values():
            total += t.packed.nbytes
            total += sum(c.nbytes for c in t.key_cols.values())
            total += sum(np.asarray(c).nbytes for c in t.value_cols.values())
        return total


@dataclasses.dataclass
class ColumnFamily:
    """One keyspace: a token ring over the canonical packed key space
    and one :class:`repro.core.ring.Partition` per ring range, each
    holding a full heterogeneous replica set of that range's rows with
    its own commit log, memtables, compaction policy and round-robin
    counter. ``slot_layouts`` (the HRCA/TR/explicit choice) is shared
    by every partition — a partition's slot-``s`` replica always
    serializes in ``slot_layouts[s]``, under global replica id
    ``vnode_id * RF + s`` (``vnode_id`` is the partition's stable
    virtual-node identity; equal to its ring position until the first
    split/merge/rebalance renumbers the ring). ``stats`` and the cost
    model keep the CF-global selectivities (the P = 1 planner's view
    and the rebuild fallback); partitioned planning ranks each
    partition's replicas with ``Partition.stats`` — that slice's own
    selectivities.

    ``replicas``/``commitlog``/``memtables``/``compaction``/
    ``rr_counter`` are flat compatibility views (the single-partition
    forms every pre-ring caller used); code that routes per partition
    goes through ``partitions`` directly."""

    name: str
    schema: KeySchema
    key_names: tuple[str, ...]
    value_names: tuple[str, ...]
    slot_layouts: tuple[tuple[str, ...], ...]
    ring: TokenRing
    partitions: list[Partition]
    stats: TableStats
    cost_model: CostModel
    hrca_result: HRCAResult | None = None
    # replica tables held as device-resident jax arrays: reads route
    # through the batched Pallas scan, and every table produced by the
    # write/recovery paths is re-placed on device
    device_resident: bool = False
    # group-commit staging threshold (0 = write-through: every write
    # flushes); the per-partition durable state lives on ``partitions``
    memtable_rows: int = 0
    # materialized per-slab aggregate views (storage.views): every
    # replica table carries per-block partial sums in its own sort
    # order; view-eligible aggregates are served O(blocks touched) and
    # the Cost Evaluator caps their row estimate at VIEW_ROWS_CAP.
    # Requires device_resident
    views: bool = False
    # observed-token histogram (P > 1 only): fed by CREATE and every
    # write, read by the rebalance drift trigger and the histogram
    # boundary proposal
    token_hist: TokenHistogram | None = None
    # next unused virtual-node id — vnode ids are never reused, so
    # migrated partitions' replica ids can never collide with live ones
    next_vnode: int = 0

    @property
    def replication_factor(self) -> int:
        return len(self.slot_layouts)

    @property
    def replicas(self) -> list[ReplicaHandle]:
        """All replica handles, flat in global-replica-id order
        (partition-major, so index == ``replica_id``)."""
        return [r for part in self.partitions for r in part.replicas]

    @property
    def commitlog(self) -> CommitLog | None:
        """Partition 0's log — THE column-family log when P == 1."""
        return self.partitions[0].commitlog

    @property
    def memtables(self) -> dict[int, Memtable]:
        """Flat ``replica_id → Memtable`` view across partitions (read
        the partitions directly to mutate)."""
        return {
            rid: mt for part in self.partitions for rid, mt in part.memtables.items()
        }

    @property
    def compaction(self) -> CompactionPolicy | None:
        return self.partitions[0].compaction

    @property
    def rr_counter(self) -> "itertools.count":
        return self.partitions[0].rr_counter

    @rr_counter.setter
    def rr_counter(self, counter: "itertools.count") -> None:
        self.partitions[0].rr_counter = counter


@dataclasses.dataclass
class ReadReport:
    replica_id: int
    node_id: int
    estimated_rows: float
    estimated_cost: float
    wall_seconds: float  # measured scan time × node slowdown
    rows_scanned: int
    hedged: bool = False


_Ranked = tuple[float, float, ReplicaHandle]  # (est_cost, est_rows, handle)


def _tie_threshold(best_cost: float) -> float:
    """Cost at or under which a replica counts as tied with the best.
    Shared by ``read`` and ``read_many`` — batched/sequential routing
    parity depends on both using the identical float expression. The
    margin is on |cost| so the threshold is ≥ best_cost even when a
    fitted cost function goes negative (negative intercept): the tie
    set always contains the cheapest replica."""
    return best_cost + abs(best_cost) * 1e-9 + 1e-12


def _schedule_picks(cost_mat: np.ndarray, counter) -> tuple[np.ndarray, np.ndarray]:
    """Request Scheduler core, shared by the single-partition and
    partitioned planners (one copy, so their routing semantics cannot
    drift): per query (column of the ``(replicas, queries)`` cost
    matrix) the within-tolerance ties are exactly the first tie_count
    entries of the column's stable ascending order — the same tie list
    a scalar ``read`` builds — and one round-robin draw is consumed per
    query in batch order. Returns ``(order, picks)``: the stable cost
    order and the picked replica row per query."""
    order = np.argsort(cost_mat, axis=0, kind="stable")  # (R, Q)
    sorted_costs = np.take_along_axis(cost_mat, order, axis=0)
    thresh = _tie_threshold(sorted_costs[0])  # elementwise over queries
    tie_counts = (sorted_costs <= thresh[None, :]).sum(axis=0)
    n_q = cost_mat.shape[1]
    draws = np.fromiter(
        (next(counter) for _ in range(n_q)), dtype=np.int64, count=n_q
    )
    return order, order[draws % tie_counts, np.arange(n_q)]


def _group_by_pick(picks: np.ndarray, qidx: list[int]) -> dict[int, list[int]]:
    """Group global query indices (``qidx[j]`` is column ``j``'s) by
    their picked replica row; one batched scan serves each group."""
    groups: dict[int, list[int]] = {}
    for j, qi in enumerate(qidx):
        groups.setdefault(int(picks[j]), []).append(qi)
    return groups


def _result_digest(
    scan: ScanResult,
    table: SortedTable,
    key_names: tuple[str, ...],
    schema: KeySchema,
) -> int:
    """Layout-independent digest of a ``ScanResult`` — what QUORUM/ALL
    reads compare across replicas (the digest-read half of Cassandra's
    read path). crc32 over:

    * ``rows_matched`` (int64) — exact and identical across layouts;
    * the aggregate value quantized to float32 — sum totals differ
      across serializations only by float summation order (~1e-15
      relative), far below float32 resolution, while a corrupted
      exponent/high bit shifts the total by orders of magnitude;
    * for selects, the *canonical* packed keys of the selected rows,
      sorted — each replica reports its own serialization order, but
      the selected row set (and hence its sorted canonical key multiset)
      is layout-independent.

    ``rows_scanned`` is deliberately excluded: it is a property of the
    serving layout, not of the answer.
    """
    h = zlib.crc32(np.int64(scan.rows_matched).tobytes())
    with np.errstate(over="ignore"):  # corrupt totals may exceed float32
        h = zlib.crc32(np.float32(scan.value).tobytes(), h)
    if scan.selected is not None and np.asarray(scan.selected).size:
        sel = np.asarray(scan.selected)
        keys = pack_columns(
            {c: table.key_cols[c][sel] for c in key_names}, key_names, schema
        )
        h = zlib.crc32(np.ascontiguousarray(np.sort(keys)), h)
    return h


class HREngine:
    """Simulated-cluster HR engine (Request Agency facade).

    ``result_cache`` (default on) keeps a per-replica map
    ``(agg, value col, filter signature) → ScanResult`` fed by both
    ``read`` and ``read_many``. The packed slab bounds are a pure
    function of (filters, layout, schema) and each replica has its own
    map, so the filter signature alone identifies the slab — keying on
    it avoids re-running the ``slab_bounds_many`` walk just to build
    keys. Writes and node recovery invalidate the affected replicas'
    entries, each per-replica map is bounded in entries
    (``result_cache_max_entries``, FIFO eviction) and in retained
    select-index bytes, and hit/miss counters are exposed on
    :attr:`stats`.
    """

    def __init__(
        self,
        n_nodes: int = 6,
        *,
        result_cache: bool = True,
        result_cache_max_entries: int = 4096,
        parallel_writes: bool = False,
        memtable_rows: int = 0,
        compaction: CompactionPolicy | None = None,
        commitlog_checkpoint_records: int = 256,
        rebalance_imbalance: float = 0.0,
        failure_detector=None,
        checksums: bool = True,
        read_retry_limit: int | None = None,
        metrics: MetricsRegistry | None = None,
        scan_timer=None,
    ) -> None:
        if n_nodes < 1:
            raise ValueError("need at least one node")
        if result_cache and result_cache_max_entries < 1:
            raise ValueError(
                "result_cache_max_entries must be >= 1; pass "
                "result_cache=False to disable caching"
            )
        if memtable_rows < 0:
            raise ValueError("memtable_rows must be >= 0 (0 = write-through)")
        if commitlog_checkpoint_records < 0:
            raise ValueError(
                "commitlog_checkpoint_records must be >= 0 (0 = no "
                "automatic checkpointing)"
            )
        self.nodes = [Node(node_id=i) for i in range(n_nodes)]
        self.column_families: dict[str, ColumnFamily] = {}
        self._cache_enabled = result_cache
        self._cache_max = result_cache_max_entries
        # operational counters live on the metrics registry (repro.obs);
        # the legacy ``stats`` dict is a read-through view and
        # ``reset_stats()`` is one registry reset. The handles bound
        # below keep the hot-path cost at one attribute load + float add.
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        for _name in ENGINE_COUNTERS:
            self.metrics.counter(_name)
        self._cache_hits = self.metrics.counter("result_cache_hits")
        self._cache_misses = self.metrics.counter("result_cache_misses")
        self._result_cache: dict[tuple[str, int], dict] = {}
        # running total of selected-array bytes per replica map, so the
        # byte budget doesn't rescan the map on every store
        self._cache_sel_bytes: dict[tuple[str, int], int] = {}
        self.parallel_writes = parallel_writes
        # write-path defaults inherited by create_column_family
        self.memtable_rows = memtable_rows
        self.compaction = compaction if compaction is not None else CompactionPolicy()
        # auto-checkpoint trigger: collapse a partition's commit log
        # after a flush once more than this many records accumulated
        # since its last snapshot (0 disables; checkpoint_commitlog
        # stays as the manual form)
        self.commitlog_checkpoint_records = commitlog_checkpoint_records
        # skew-drift auto-rebalance: after a write-path flush, any P > 1
        # column family whose token histogram puts more than
        # ``rebalance_imbalance`` × the mean row mass in one partition
        # is rebalanced in place (0 disables; ``rebalance`` stays as
        # the manual form)
        if rebalance_imbalance < 0:
            raise ValueError("rebalance_imbalance must be >= 0 (0 = manual only)")
        self.rebalance_imbalance = rebalance_imbalance
        # availability layer: optional accrual failure detector (duck-
        # typed — record/record_failure/cost_factor; see ft.detector),
        # content checksums on installed tables (scrub's witness), and
        # the failover bound for transient read faults (None = one
        # attempt per live replica)
        self.failure_detector = failure_detector
        self.checksums = bool(checksums)
        # scan-wall clock: the walls fed to the failure detector (and
        # attributed to ReadReports) come from this zero-arg callable.
        # ``time.perf_counter`` by default; a deterministic counter
        # (e.g. repro.obs.TickClock) makes detector state — and hence
        # replica routing — a pure function of the operation sequence,
        # which the chaos byte-identical-trace property requires
        self._scan_timer = scan_timer if scan_timer is not None else time.perf_counter
        # the limit counts ATTEMPTS (first try included), so anything
        # below 1 is nonsense: 0 used to slip through both retry loops
        # as "zero attempts allowed", turning the first transient fault
        # into an immediate unanswerable-query RuntimeError
        if read_retry_limit is not None and read_retry_limit < 1:
            raise ValueError(
                "read_retry_limit must be >= 1 (attempts, first try "
                "included; None = one attempt per live replica), got "
                f"{read_retry_limit}"
            )
        self.read_retry_limit = read_retry_limit
        self._hints_queued = self.metrics.counter("hints_queued")
        self._hint_replays = self.metrics.counter("hint_replays")
        self._hint_rows_replayed = self.metrics.counter("hint_rows_replayed")
        self._hint_fallbacks = self.metrics.counter("hint_fallbacks")
        self._digest_mismatches = self.metrics.counter("digest_mismatches")
        self._read_repairs = self.metrics.counter("read_repairs")
        self._read_retries = self.metrics.counter("read_retries")
        self._scrub_checks = self.metrics.counter("scrub_checks")
        self._scrub_repairs = self.metrics.counter("scrub_repairs")
        self._flushes = self.metrics.counter("memtable_flushes")
        self._compactions = self.metrics.counter("compactions")
        self._auto_checkpoints = self.metrics.counter("commitlog_auto_checkpoints")
        # migration observability (satellite counters)
        self._partition_splits = self.metrics.counter("partition_splits")
        self._partition_merges = self.metrics.counter("partition_merges")
        self._rebalance_rows_moved = self.metrics.counter("rebalance_rows_moved")
        self._empty_partition_skips = self.metrics.counter("empty_partition_skips")
        # typed refusals/faults (FAULT_COUNTERS): raised-or-survived
        # exceptions, each visible in the registry at the raise site
        self._deadline_exceeded = self.metrics.counter("deadline_exceeded")
        self._read_faults = self.metrics.counter("read_faults")
        self._flush_faults = self.metrics.counter("flush_faults")
        self._corrupt_runs = self.metrics.counter("corrupt_runs")
        # cumulative seconds spent in memtable flushes (incl. the ones
        # a read barrier triggers, which are write-path cost and NOT
        # attributed to any ReadReport.wall_seconds)
        self._flush_wall = self.metrics.counter("flush_wall_seconds")
        # materialized per-slab aggregate views: queries answered from
        # block partials, window-edge rows the boundary rescan touched,
        # and full view rebuilds (create / compaction / migration /
        # scrub heal — incremental flush extensions are NOT rebuilds)
        self._view_hits = self.metrics.counter("view_hits")
        self._view_boundary_rows = self.metrics.counter("view_boundary_rows")
        self._view_rebuilds = self.metrics.counter("view_rebuilds")
        self._pool: ThreadPoolExecutor | None = None

    @property
    def _executor(self) -> ThreadPoolExecutor:
        """Shared flush thread pool, created lazily on first parallel
        flush — a per-flush pool's startup cost would eat into the
        overlap ``benchmarks/write_queue.py`` measures."""
        if self._pool is None:
            self._pool = ThreadPoolExecutor(max_workers=8)
        return self._pool

    def __getstate__(self) -> dict:
        # thread pools hold locks/threads and cannot be (deep)copied or
        # pickled; drop it — it is recreated lazily on first use
        state = self.__dict__.copy()
        state["_pool"] = None
        return state

    # -- result cache --------------------------------------------------------

    @property
    def stats(self) -> dict:
        """Operational counters: per-replica read result cache plus the
        durable write path (log records/rows, currently staged rows,
        memtable flushes and automatic compactions).

        A read-through view: counter-backed keys come from
        :attr:`metrics` (see ``ENGINE_COUNTERS``), structural keys
        (log records, staged rows, open hints, cache occupancy) are
        computed live from the storage structures they describe —
        they are state, not events, so ``reset_stats`` leaves them."""
        parts = [p for cf in self.column_families.values() for p in cf.partitions]
        return {
            "result_cache_hits": int(self._cache_hits.value),
            "result_cache_misses": int(self._cache_misses.value),
            "result_cache_entries": sum(
                len(c) for c in self._result_cache.values()
            ),
            "result_cache_select_bytes": sum(self._cache_sel_bytes.values()),
            "partitions": len(parts),
            "commitlog_records": sum(
                len(p.commitlog) for p in parts if p.commitlog is not None
            ),
            "commitlog_rows": sum(
                p.commitlog.n_rows for p in parts if p.commitlog is not None
            ),
            "commitlog_auto_checkpoints": int(self._auto_checkpoints.value),
            "staged_rows": sum(
                mt.n_staged for p in parts for mt in p.memtables.values()
            ),
            "memtable_flushes": int(self._flushes.value),
            "compactions": int(self._compactions.value),
            # ring-migration observability: boundary insertions/removals
            # and the rows whose partition ownership a migration rebuilt
            "partition_splits": int(self._partition_splits.value),
            "partition_merges": int(self._partition_merges.value),
            "rebalance_rows_moved": int(self._rebalance_rows_moved.value),
            # (partition, query) launches the scatter path skipped
            # because the partition provably held no rows in the slab
            "empty_partition_skips": int(self._empty_partition_skips.value),
            # availability layer: writes that accrued a hint for a
            # transiently-down replica; node-up heals served from the
            # hinted tail vs. full-rebuild fallbacks; digest reads;
            # failover retries; scrub activity
            "hints_open": sum(len(p.hints) for p in parts),
            "hints_queued": int(self._hints_queued.value),
            "hint_replays": int(self._hint_replays.value),
            "hint_rows_replayed": int(self._hint_rows_replayed.value),
            "hint_fallbacks": int(self._hint_fallbacks.value),
            "digest_mismatches": int(self._digest_mismatches.value),
            "read_repairs": int(self._read_repairs.value),
            "read_retries": int(self._read_retries.value),
            "scrub_checks": int(self._scrub_checks.value),
            "scrub_repairs": int(self._scrub_repairs.value),
            # typed refusals and faults survived via failover
            # (FAULT_COUNTERS)
            "deadline_exceeded": int(self._deadline_exceeded.value),
            "read_faults": int(self._read_faults.value),
            "flush_faults": int(self._flush_faults.value),
            "corrupt_runs": int(self._corrupt_runs.value),
            # cumulative wall of ALL flushes. Flushes inside write()
            # (write-through or threshold-crossing) also count toward
            # that write's returned wall — don't sum the two. The
            # counter exists because read-barrier flushes appear in
            # neither write()'s return nor any ReadReport.wall_seconds;
            # here is the only place that time is visible
            "flush_wall_seconds": self._flush_wall.value,
            # materialized aggregate views: view-routed answers, edge
            # rows the boundary rescan touched, full rebuilds
            "view_hits": int(self._view_hits.value),
            "view_boundary_rows": int(self._view_boundary_rows.value),
            "view_rebuilds": int(self._view_rebuilds.value),
        }

    def reset_stats(self) -> None:
        """Zero every registry-backed counter in place (benchmarks used
        to re-construct engines just to get clean counters). Structural
        ``stats`` keys — log records, staged rows, open hints, cache
        occupancy — describe live state and are untouched."""
        self.metrics.reset()

    def _check_deadline(
        self, deadline_at: float | None, budget_s: float | None
    ) -> None:
        """Raise :class:`DeadlineExceeded` once the budget is spent.
        Called before each unit of *required* work (a replica-group
        scan, a failover retry, a digest read); optional work (hedges)
        is skipped instead of raising — the primary answer stands.
        Every raise is visible as the ``deadline_exceeded`` counter."""
        if deadline_at is not None and time.perf_counter() >= deadline_at:
            self._deadline_exceeded.inc()
            raise DeadlineExceeded(budget_s)

    @staticmethod
    def _cache_keys(queries: list[Query]) -> list:
        """One key per query: aggregation + value column + filter
        signature. The cache is per-replica, so layout (and with it the
        packed slab bounds, a pure function of the filters) is implicit
        — no bounds walk on the hot path just to build keys."""
        return [
            (q.agg, q.value_col, tuple(sorted(q.filters.items())))
            for q in queries
        ]

    # a select's cached index array may be arbitrarily large; entries
    # past the per-entry byte size are served but never cached, and each
    # replica map evicts FIFO until its retained selected-array bytes
    # fit the map budget — so worst-case memory is bounded per replica
    # by min(max_entries × entry cap, map budget), not by table size
    _CACHE_MAX_SELECT_BYTES = 1 << 20
    _CACHE_MAX_MAP_BYTES = 64 << 20

    def _cache_store(self, map_key, cache: dict, key, result: ScanResult) -> None:
        """Cache hits hand out the same ScanResult object, so a select's
        index array is frozen on the way in — a caller mutating it would
        otherwise corrupt every later hit. Each per-replica map is
        bounded in entries (``result_cache_max_entries``, FIFO) and in
        selected-array bytes: workloads of all-distinct (select)
        queries must not grow memory without bound."""
        nb = 0 if result.selected is None else int(result.selected.nbytes)
        if nb > self._CACHE_MAX_SELECT_BYTES or nb > self._CACHE_MAX_MAP_BYTES:
            # uncacheable either way: over the per-entry cap, or (only
            # reachable when the budgets are tuned so a single entry can
            # exceed the whole map budget) it would leave the map over
            # budget even after the eviction loop emptied it
            return
        if result.selected is not None:
            result.selected.setflags(write=False)
        total = self._cache_sel_bytes.get(map_key, 0)
        old = cache.pop(key, None)
        if old is not None and old.selected is not None:
            total -= old.selected.nbytes
        while cache and (
            len(cache) >= self._cache_max
            or total + nb > self._CACHE_MAX_MAP_BYTES
        ):
            evicted = cache.pop(next(iter(cache)))
            if evicted.selected is not None:
                total -= evicted.selected.nbytes
        cache[key] = result
        self._cache_sel_bytes[map_key] = total + nb

    def _invalidate_result_cache(
        self,
        cf_name: str,
        node_id: int | None = None,
        replica_id: int | None = None,
    ) -> None:
        cf = self.column_families[cf_name]
        for r in cf.replicas:
            if node_id is not None and r.node_id != node_id:
                continue
            if replica_id is not None and r.replica_id != replica_id:
                continue
            self._result_cache.pop((cf_name, r.replica_id), None)
            self._cache_sel_bytes.pop((cf_name, r.replica_id), None)

    # -- Replica Generator ---------------------------------------------------

    def _place(self, replica_id: int, cf_name: str) -> int:
        """Replica placement hash(replica_id, cf) → node. Successive
        replicas land on distinct nodes when possible (Cassandra ring);
        with global replica ids (``partition_id * RF + slot``)
        successive partitions stagger around the node ring too.

        Delegates to ``repro.core.ring.place_replica`` — crc32, not
        ``hash``: the builtin is salted per process (PYTHONHASHSEED),
        which made placement — and every benchmark downstream of it —
        differ between runs.
        """
        return place_replica(cf_name, replica_id, len(self.nodes))

    def create_column_family(
        self,
        name: str,
        key_cols: Mapping[str, np.ndarray],
        value_cols: Mapping[str, np.ndarray],
        *,
        replication_factor: int = 3,
        mechanism: str = "HR",
        workload: Workload | None = None,
        schema: KeySchema | None = None,
        cost_fns: dict[int, LinearCostFunction] | None = None,
        hrca_kwargs: dict | None = None,
        layouts: Sequence[Sequence[str]] | None = None,
        device_resident: bool = False,
        views: bool = False,
        memtable_rows: int | None = None,
        compaction: CompactionPolicy | None = None,
        partitions: int = 1,
        partition_balance: str = "equal",
    ) -> ColumnFamily:
        """CREATE COLUMN FAMILY: choose replica structures, build tables.

        mechanism:
          "HR" — layouts from HRCA over ``workload`` (paper).
          "TR" — the single best expert layout, identical on all replicas
                 (the paper's baseline: "approximate optimal structure
                 that an expert can give"); exhaustive for ≤5 keys, else
                 single-replica annealing + greedy polish.
        Explicit ``layouts`` override both (tests / ablations).

        With ``device_resident=True`` every replica table is placed on
        device at creation: ``read``/``read_many`` then answer sum,
        count and select queries with the fused locate+scan Pallas
        launch instead of the numpy engine, writes *append* to the
        resident arrays (incremental placement — no re-upload), and
        recovery re-places rebuilt tables. Raises if the schema exceeds
        the device path's per-column two-lane budget.

        ``views=True`` (requires ``device_resident``) additionally
        materializes per-slab aggregate views on every replica table:
        per-block partial sums of the value tile in that replica's own
        sort order (``repro.core.storage.views``). View-eligible sum and
        count queries — slab filters forming a prefix of the layout —
        are then answered from the stored partials plus a rescan of at
        most the two window-edge blocks, O(blocks touched) instead of
        O(N), bit-identical to the fused full scan. Views extend
        incrementally at flush, rebuild at compaction and migration,
        and are treated as derived state everywhere else (scrub heals a
        corrupted view by rebuilding it from the resident arrays).

        ``memtable_rows`` (default: the engine's) is the group-commit
        staging threshold — 0 means write-through, every ``write``
        flushes. ``compaction`` (default: the engine's policy) bounds
        the device run stack; pass an explicit ``CompactionPolicy`` to
        tune its thresholds. The CREATE-time dataset is committed as
        record 0 of the column family's shared commit log, so replaying
        the log alone rebuilds any replica.

        ``partitions`` splits the keyspace Cassandra-style: a token
        ring over the canonical packed key range assigns every row to
        one of ``P`` contiguous token ranges, each owning a full
        heterogeneous replica set of just its rows, its own commit log,
        memtables and compaction policy (``repro.core.ring``). Reads
        scatter over the partitions a query's slab can touch and gather
        partial aggregates on the host; writes route rows to the owning
        partitions' logs. ``partitions=1`` (default) is bit-identical
        to the unpartitioned engine.

        ``partition_balance`` picks the initial boundaries: ``"equal"``
        (default) splits the key *space* evenly — the historical,
        skew-oblivious form; ``"tokens"`` places the boundaries at
        exact quantiles of the CREATE dataset's observed tokens, so a
        Zipf-skewed keyspace starts balanced in *rows* instead
        (``TokenRing.from_tokens``; ``rebalance`` applies the same
        boundaries to a live column family). Either way each partition
        carries its own ``TableStats`` (P > 1) and the planner ranks
        its replicas with that partition's selectivities; with
        ``mechanism="HR"`` the HRCA search itself optimizes the
        row-fraction-weighted blend of per-partition cost models.
        """
        if name in self.column_families:
            raise ValueError(f"column family {name!r} exists")
        if views and not device_resident:
            raise ValueError(
                "views=True requires device_resident=True (views are "
                "per-block partials of the resident value tile)"
            )
        if schema is None:
            schema = KeySchema.for_columns(key_cols)
        key_names = tuple(key_cols)
        stats = TableStats.from_columns(key_cols, schema)
        model = CostModel(stats=stats, cost_fns=dict(cost_fns or {}))
        n = replication_factor
        hrca_result: HRCAResult | None = None

        # ring + per-partition stats come BEFORE the layout choice: the
        # HR search over a partitioned CF optimizes against each
        # partition's own selectivities, not the CF-global blend
        value_names = tuple(value_cols)
        policy = compaction if compaction is not None else self.compaction
        tokens = token_hist = None
        part_stats: list[TableStats | None]
        if partitions == 1:
            ring = TokenRing.build(schema, key_names, 1)
            owner_masks: list = [None]  # whole dataset, no slicing copies
            part_stats = [None]
        else:
            kc_arr = {c: np.asarray(key_cols[c]) for c in key_names}
            tokens = pack_columns(kc_arr, key_names, schema)
            if partition_balance == "equal":
                ring = TokenRing.build(schema, key_names, partitions)
            elif partition_balance == "tokens":
                ring = TokenRing.from_tokens(schema, key_names, tokens, partitions)
            else:
                raise ValueError(
                    f"unknown partition_balance {partition_balance!r} "
                    "(expected 'equal' or 'tokens')"
                )
            pids = ring.partition_of_tokens(tokens)
            owner_masks = [pids == pid for pid in range(partitions)]
            part_stats = [
                TableStats.from_columns(
                    {c: kc_arr[c][mask] for c in key_names}, schema
                )
                for mask in owner_masks
            ]
            token_hist = TokenHistogram.build(ring.total_bits)
            token_hist.add_tokens(tokens, device=device_resident)

        if layouts is not None:
            chosen = tuple(tuple(a) for a in layouts)
            if len(chosen) != n:
                raise ValueError("len(layouts) != replication_factor")
        elif mechanism == "TR":
            if workload is None:
                chosen = tuple(tuple(key_names) for _ in range(n))
            else:
                best = self._expert_layout(model, workload, key_names)
                chosen = tuple(best for _ in range(n))
        elif mechanism == "HR":
            if workload is None:
                raise ValueError("HR mechanism needs a workload for HRCA")
            kw = dict(hrca_kwargs or {})
            if partitions == 1:
                hrca_model = model
            else:
                # per-partition models weighted by row fraction — the
                # shared layout set is optimized for what each
                # partition actually serves (see hrca._MemoCost)
                hrca_model = [
                    (
                        float(ps.n_rows),
                        CostModel(stats=ps, cost_fns=dict(cost_fns or {})),
                    )
                    for ps in part_stats
                ]
            hrca_result = hrca(hrca_model, workload, initial_state(key_names, n), **kw)
            chosen = hrca_result.layouts
        else:
            raise ValueError(f"unknown mechanism {mechanism!r}")

        parts: list[Partition] = []
        for pid, mask in enumerate(owner_masks):
            if mask is None:
                kc_p, vc_p = key_cols, value_cols
            else:
                kc_p = {c: np.asarray(key_cols[c])[mask] for c in key_names}
                vc_p = {c: np.asarray(value_cols[c])[mask] for c in value_names}
            handles: list[ReplicaHandle] = []
            memtables: dict[int, Memtable] = {}
            part_digest: int | None = None  # layout-independent: one
            for slot, layout in enumerate(chosen):  # digest per partition
                rid = pid * n + slot
                table = SortedTable.from_columns(kc_p, vc_p, layout, schema)
                if device_resident:
                    table.place_on_device()
                    if views:
                        table.build_views()
                if self.checksums:
                    if part_digest is None:
                        part_digest = table.seal_checksum().stored_digest
                    else:
                        table.stored_digest = part_digest
                node_id = self._place(rid, name)
                self.nodes[node_id].tables[(name, rid)] = table
                handles.append(
                    ReplicaHandle(rid, tuple(layout), node_id, partition_id=pid)
                )
                memtables[rid] = Memtable(layout, schema, key_names, value_names)
            log = CommitLog(key_names=key_names, value_names=value_names)
            log.append(kc_p, vc_p)  # record 0: the rows this partition owns
            lo, hi = ring.token_range(pid)
            part = Partition(
                partition_id=pid,
                token_lo=lo,
                token_hi=hi,
                replicas=handles,
                commitlog=log,
                memtables=memtables,
                compaction=policy,
                vnode_id=pid,  # birth identity == ring position at CREATE
                stats=part_stats[pid],
                # every replica table holds exactly record 0 — complete
                # through the log's current tail
                flushed_lsn={r.replica_id: log.next_lsn for r in handles},
            )
            if tokens is not None:
                part.observe_tokens(tokens[owner_masks[pid]])
            parts.append(part)

        cf = ColumnFamily(
            name=name,
            schema=schema,
            key_names=key_names,
            value_names=value_names,
            slot_layouts=tuple(tuple(a) for a in chosen),
            ring=ring,
            partitions=parts,
            stats=stats,
            cost_model=model,
            hrca_result=hrca_result,
            device_resident=device_resident,
            views=views,
            memtable_rows=(
                self.memtable_rows if memtable_rows is None else memtable_rows
            ),
            token_hist=token_hist,
            next_vnode=partitions,
        )
        self.column_families[name] = cf
        return cf

    @staticmethod
    def _expert_layout(
        model: CostModel, workload: Workload, key_names: tuple[str, ...]
    ) -> tuple[str, ...]:
        if len(key_names) <= 5:
            state, _ = exhaustive_search(model, workload, key_names, 1)
            return state[0]
        res = hrca(
            model, workload, initial_state(key_names, 1), greedy_descent=True, k_max=2000
        )
        return res.layouts[0]

    # -- Cost Evaluator / Request Scheduler -----------------------------------

    def _table(self, cf: ColumnFamily, r: ReplicaHandle) -> SortedTable:
        return self.nodes[r.node_id].tables[(cf.name, r.replica_id)]

    def _ranked_replicas(self, cf: ColumnFamily, query: Query) -> list[_Ranked]:
        """Replicas on live nodes ranked by estimated cost (Eq 2–3),
        multiplied by the failure detector's per-node cost factor when
        one is attached (suspected nodes are down-ranked, not excluded)."""
        det = self.failure_detector
        ranked: list[_Ranked] = []
        for r in cf.replicas:
            if not self.nodes[r.node_id].alive:
                continue
            rows = estimate_rows(cf.stats, r.layout, query)
            cost = cf.cost_model.cost_fn(len(r.layout))(rows)
            if (
                cf.views
                and query.agg in VIEW_AGGS
                and query_view_eligible(query, r.layout)
            ):
                # view term (Eq 1–2 refined): a view-eligible aggregate
                # touches at most the two window-edge blocks, so its
                # row estimate is capped — the planner learns that a
                # view hit beats a full scan regardless of selectivity
                cost = cf.cost_model.cost_fn(len(r.layout))(
                    min(rows, float(VIEW_ROWS_CAP))
                )
            if det is not None:
                cost *= det.cost_factor(r.node_id)
            ranked.append((cost, rows, r))
        if not ranked:
            raise RuntimeError(f"no live replica for {cf.name!r}")
        ranked.sort(key=lambda t: t[0])
        return ranked

    def _live_cost_factors(self, live: list[ReplicaHandle]) -> np.ndarray | None:
        """Per-live-replica detector cost factors (None when no detector
        is attached — the cost matrices then stay bit-identical to the
        detector-free engine)."""
        det = self.failure_detector
        if det is None:
            return None
        return np.array([det.cost_factor(r.node_id) for r in live], dtype=np.float64)

    def read(
        self,
        cf_name: str,
        query: Query,
        *,
        hedge: bool = False,
        hedge_ratio: float = 2.0,
        consistency: str = ONE,
        deadline_s: float | None = None,
        trace=None,
    ) -> tuple[ScanResult, ReadReport]:
        """Route to the cheapest live replica; ties broken round-robin
        (load balance). With ``hedge=True`` a read landing on a straggler
        node (slowdown > hedge_ratio) is duplicated on the next-cheapest
        replica on a *different* node; the faster copy wins.
        ``consistency`` beyond ``ONE`` adds digest reads on the next
        cost-ranked replicas with read repair on mismatch (module
        docstring, availability layer).

        ``deadline_s`` is a latency *budget* for this call: required
        work (the primary scan, failover retries, digest reads) checks
        the remaining budget before launching and raises
        :class:`DeadlineExceeded` once it is spent — the request is shed
        explicitly instead of served late; optional work (the hedge
        duplicate) is silently skipped when no budget remains. ``None``
        (default) is unbounded; a non-positive budget sheds before any
        scan.

        The common case (single partition, ``consistency=ONE``) runs a
        scalar fast path: one ``_ranked_replicas`` pass instead of the
        batched planner's full cost/order matrices — same costs, same
        tie rule, same RR counter, so routing stays identical to
        ``read_many`` at Q = 1 (parity-tested) at a fraction of the
        per-call planning cost. Partitioned CFs and higher consistency
        levels delegate to the batched planner at Q = 1.

        ``trace`` (an open :class:`repro.obs.Span`, or None) hangs this
        call's span subtree under the caller's — ``engine.read`` for
        the scalar fast path (see the taxonomy in
        :mod:`repro.obs.trace`). Tracing disabled (None) costs one
        ``is None`` test per stage.
        """
        if consistency not in CONSISTENCY_LEVELS:
            raise ValueError(
                f"consistency must be one of {CONSISTENCY_LEVELS}, "
                f"got {consistency!r}"
            )
        cf = self.column_families[cf_name]
        if cf.ring.n_partitions > 1 or consistency != ONE:
            return self.read_many(
                cf_name,
                [query],
                hedge=hedge,
                hedge_ratio=hedge_ratio,
                consistency=consistency,
                deadline_s=deadline_s,
                trace=trace,
            )[0]
        deadline = _deadline_at(deadline_s)
        self._check_deadline(deadline, deadline_s)
        span = (
            trace.child("engine.read", cf=cf_name, level=consistency)
            if trace is not None
            else None
        )
        try:
            ranked = self._ranked_replicas(cf, query)
            best_cost = ranked[0][0]
            ties = [t for t in ranked if t[0] <= _tie_threshold(best_cost)]
            entry = ties[next(cf.rr_counter) % len(ties)]

            # same failover semantics as _run_groups: a transient fault
            # advances to the next-ranked untried replica, bounded by the
            # live count (or read_retry_limit)
            limit = len(ranked) if self.read_retry_limit is None else self.read_retry_limit
            tried: set[int] = set()
            while True:
                tried.add(entry[2].replica_id)
                try:
                    result, report = self._execute_scalar(
                        cf, entry, query, hedged=False, trace=span,
                        retry=bool(tried - {entry[2].replica_id}),
                    )
                    break
                except TransientFault:
                    self._read_retries.inc()
                    self._check_deadline(deadline, deadline_s)
                    entry = next(
                        (t for t in ranked if t[2].replica_id not in tried), None
                    )
                    if entry is None or len(tried) >= limit:
                        raise RuntimeError(
                            f"no live replica answered query 0 of {cf.name!r} "
                            f"after {len(tried)} attempts"
                        ) from None

            if (
                hedge
                and len(ranked) > 1
                and self.nodes[report.node_id].slowdown > hedge_ratio
                and not _deadline_spent(deadline)  # hedging is optional work
            ):
                alt = next(
                    (t for t in ranked if t[2].node_id != report.node_id), None
                )
                if alt is not None:
                    try:
                        r2, rep2 = self._execute_scalar(
                            cf, alt, query, hedged=True, trace=span
                        )
                    except TransientFault:
                        pass  # best-effort duplicate; the primary stands
                    else:
                        # ties go to the hedge — cache hits serve at zero
                        # attributed wall on both sides (see _execute_group)
                        if rep2.wall_seconds <= report.wall_seconds:
                            return r2, rep2
            return result, report
        finally:
            if span is not None:
                span.end()

    def _execute_scalar(
        self, cf: ColumnFamily, entry: _Ranked, query: Query, *,
        hedged: bool, trace=None, retry: bool = False,
    ) -> tuple[ScanResult, ReadReport]:
        """Execute one query on one replica through the shared
        cache/fault/detector path (``_scan_with_cache``)."""
        est_cost, est_rows, r = entry
        g = (
            trace.child(
                "engine.group_scan", replica=r.replica_id, node=r.node_id,
                queries=1, hedged=hedged, retry=retry,
            )
            if trace is not None
            else None
        )
        try:
            scans, walls = self._scan_with_cache(cf, r, [query], trace=g)
        except TransientFault as e:
            if g is not None:
                g.end(error=type(e).__name__)
            raise
        if g is not None:
            g.end(rows=int(scans[0].rows_scanned))
        return scans[0], ReadReport(
            replica_id=r.replica_id,
            node_id=r.node_id,
            estimated_rows=est_rows,
            estimated_cost=est_cost,
            wall_seconds=walls[0],
            rows_scanned=scans[0].rows_scanned,
            hedged=hedged,
        )

    def read_many(
        self,
        cf_name: str,
        queries: Sequence[Query],
        *,
        hedge: bool = False,
        hedge_ratio: float = 2.0,
        consistency: str = ONE,
        deadline_s: float | None = None,
        trace=None,
    ) -> list[tuple[ScanResult, ReadReport]]:
        """Batched ``read``: one scheduler pass and one grouped storage
        scan for the whole batch (see module docstring for semantics).

        Returns per-query ``(ScanResult, ReadReport)`` in batch order;
        results and routing decisions are identical to calling ``read``
        on each query in sequence. ``consistency="QUORUM"``/``"ALL"``
        additionally executes every query on the next cost-ranked
        replicas up to the level's k, compares layout-independent result
        digests and repairs divergent replicas from the commit log
        (read repair); the returned result is always the digest-majority
        answer. ``deadline_s`` bounds the whole batch's latency budget:
        required work (replica-group scans, failover retries, digest
        reads) raises :class:`DeadlineExceeded` once the budget is
        spent, while optional work (hedge duplicates) is silently
        skipped — the call either answers within budget or fails
        loudly, never silently slow.

        ``trace`` (an open :class:`repro.obs.Span`, or None) hangs an
        ``engine.read_many`` subtree — planning, per-(partition,
        replica) group scans down to the kernel launches, digest pass,
        gather — under the caller's span; see the stage taxonomy in
        :mod:`repro.obs.trace`. ``None`` (default) keeps the hot path
        untraced at the cost of one ``is None`` test per stage.
        """
        if consistency not in CONSISTENCY_LEVELS:
            raise ValueError(
                f"unknown consistency {consistency!r} "
                f"(expected one of {CONSISTENCY_LEVELS})"
            )
        cf = self.column_families[cf_name]
        queries = list(queries)
        if not queries:
            return []
        deadline = _deadline_at(deadline_s)
        self._check_deadline(deadline, deadline_s)
        span = (
            trace.child(
                "engine.read_many", cf=cf_name, queries=len(queries),
                level=consistency,
            )
            if trace is not None
            else None
        )
        try:
            if cf.ring.n_partitions > 1:
                return self._read_many_partitioned(
                    cf,
                    queries,
                    hedge=hedge,
                    hedge_ratio=hedge_ratio,
                    consistency=consistency,
                    deadline_at=deadline,
                    budget_s=deadline_s,
                    trace=span,
                )
            live = [r for r in cf.replicas if self.nodes[r.node_id].alive]
            if not live:
                raise RuntimeError(f"no live replica for {cf_name!r}")
            n_q = len(queries)

            # vectorized Cost Evaluator: Eq (1)-(2) over all (replica,
            # query); per-column selectivities are extracted once and
            # shared by all replica layouts
            plan = span.child("engine.plan") if span is not None else None
            pre = precompute_query_stats(cf.stats, queries, cf.key_names)
            rows_mat = np.stack(
                [estimate_rows_many(cf.stats, r.layout, queries, pre) for r in live]
            )
            cost_mat = np.stack(
                [
                    cf.cost_model.cost_fn(len(r.layout)).many(rows_mat[k])
                    for k, r in enumerate(live)
                ]
            )
            # view term: cap eligible (replica, query) row estimates.
            # The any() guard doubles as the all-select fast path — a
            # batch with no sum/count never walks the eligibility arrays
            if cf.views and any(q.agg in VIEW_AGGS for q in queries):
                elig = view_eligible_matrix([r.layout for r in live], queries)
                if elig.any():
                    capped = np.minimum(rows_mat, float(VIEW_ROWS_CAP))
                    cost_mat = np.where(
                        elig,
                        np.stack(
                            [
                                cf.cost_model.cost_fn(len(r.layout)).many(
                                    capped[k]
                                )
                                for k, r in enumerate(live)
                            ]
                        ),
                        cost_mat,
                    )
            factors = self._live_cost_factors(live)
            if factors is not None:
                cost_mat = cost_mat * factors[:, None]

            # Request Scheduler: per-query cheapest replica, RR tie-break
            # (one draw per query in batch order, so a batch matches a
            # sequential read loop); then one batched scan per chosen group,
            # with bounded failover onto the next-ranked replica when a scan
            # raises a transient fault
            order_mat, picks = _schedule_picks(cost_mat, cf.rr_counter)
            if plan is not None:
                plan.end(replicas=len(live))
            all_q = list(range(n_q))
            results: list[ScanResult | None] = [None] * n_q
            reports: list[ReadReport | None] = [None] * n_q
            self._run_groups(
                cf, live, order_mat, picks, all_q, queries, rows_mat, cost_mat,
                results, reports, deadline_at=deadline, budget_s=deadline_s,
                trace=span,
            )

            if hedge and len(live) > 1 and not _deadline_spent(deadline):
                # duplicate straggler-bound queries onto the next-cheapest
                # replica on a different node (same alternate ``read`` picks);
                # hedges are best-effort duplicates — a faulting hedge is
                # dropped, never failed over (the primary result stands)
                for k, qidx in self._hedge_groups(
                    live, order_mat, picks, all_q, hedge_ratio
                ).items():
                    try:
                        self._execute_group(
                            cf, live[k], qidx, queries, rows_mat[k], cost_mat[k],
                            results, reports, hedged=True, trace=span,
                        )
                    except TransientFault:
                        continue

            if consistency != ONE:
                self._consistency_pass(
                    cf, cf.partitions[0], live, order_mat, picks, all_q,
                    queries, results, reports, consistency,
                    deadline_at=deadline, budget_s=deadline_s, trace=span,
                )

            return list(zip(results, reports))  # type: ignore[arg-type]
        finally:
            if span is not None:
                span.end()

    def _run_groups(
        self,
        cf: ColumnFamily,
        live: list[ReplicaHandle],
        order: np.ndarray,
        picks: np.ndarray,
        qidx: list[int],
        queries: list[Query],
        rows_live: np.ndarray,
        cost_live: np.ndarray,
        results: list,
        reports: list,
        *,
        deadline_at: float | None = None,
        budget_s: float | None = None,
        trace=None,
    ) -> None:
        """Primary grouped execution with bounded failover: queries
        whose group raises a :class:`TransientFault` advance to the
        next replica in their cost order that was not yet tried
        (``read_retries`` counts each re-routed query), up to
        ``read_retry_limit`` attempts per query (default: one per live
        replica). Scheduler column ``j`` of ``order`` corresponds to
        global query index ``qidx[j]``. A spent ``deadline_at`` budget
        raises :class:`DeadlineExceeded` before the next group scan."""
        col_of = {qi: j for j, qi in enumerate(qidx)}
        limit = (
            len(live) if self.read_retry_limit is None else self.read_retry_limit
        )
        tried: dict[int, set[int]] = {qi: set() for qi in qidx}
        queue = [(k, sub, False) for k, sub in _group_by_pick(picks, qidx).items()]
        while queue:
            self._check_deadline(deadline_at, budget_s)
            k, sub, is_retry = queue.pop(0)
            for qi in sub:
                tried[qi].add(k)
            try:
                self._execute_group(
                    cf, live[k], sub, queries, rows_live[k], cost_live[k],
                    results, reports, hedged=False, trace=trace,
                    retry=is_retry,
                )
            except TransientFault:
                self._read_retries.inc(len(sub))
                retry: dict[int, list[int]] = {}
                for qi in sub:
                    nxt = (
                        next(
                            (
                                int(x)
                                for x in order[:, col_of[qi]]
                                if int(x) not in tried[qi]
                            ),
                            None,
                        )
                        if len(tried[qi]) < limit
                        else None
                    )
                    if nxt is None:
                        raise RuntimeError(
                            f"no live replica answered query {qi} of "
                            f"{cf.name!r} after {len(tried[qi])} attempts"
                        )
                    retry.setdefault(nxt, []).append(qi)
                queue.extend((k2, sub2, True) for k2, sub2 in retry.items())

    def _scan_with_cache(
        self, cf: ColumnFamily, r: ReplicaHandle, group: list[Query],
        *, trace=None,
    ) -> tuple[list[ScanResult], list[float]]:
        """Core scan for one replica's query group: read-barrier flush,
        injected-fault check, result cache, one ``execute_many`` for
        the misses, failure-detector feed. Returns per-query
        ``(scans, walls)`` aligned with ``group``; cache hits carry
        zero attributed wall. Raises :class:`TransientReadError` /
        :class:`TransientFlushError` *before* producing any result, so
        a faulting group is retried whole."""
        self._ensure_flushed(cf, r, trace=trace)  # may raise TransientFlushError
        table = self._table(cf, r)
        cp = trace.child("engine.cache_probe") if trace is not None else None
        cache = ckeys = None
        if self._cache_enabled:
            cache = self._result_cache.setdefault((cf.name, r.replica_id), {})
            ckeys = self._cache_keys(group)
        hit_j = set() if cache is None else {j for j, k in enumerate(ckeys) if k in cache}
        miss_j = [j for j in range(len(group)) if j not in hit_j]
        if cp is not None:
            cp.end(hits=len(hit_j), misses=len(miss_j))
        node = self.nodes[r.node_id]
        if miss_j and node.read_fault_budget > 0:
            node.read_fault_budget -= 1
            if self.failure_detector is not None:
                self.failure_detector.record_failure(node.node_id)
            self._read_faults.inc()
            raise TransientReadError(node.node_id)
        sc = (
            trace.child("engine.scan", queries=len(miss_j))
            if trace is not None and miss_j
            else None
        )
        vstats = {"hits": 0, "boundary_rows": 0} if cf.views else None
        t0 = self._scan_timer()
        miss_scans = (
            table.execute_many(
                [group[j] for j in miss_j], trace=sc, view_stats=vstats
            )
            if miss_j
            else []
        )
        wall = (self._scan_timer() - t0) * node.slowdown
        if sc is not None:
            sc.end(rows=int(sum(s.rows_scanned for s in miss_scans)))
        if vstats is not None and vstats["hits"]:
            self._view_hits.inc(vstats["hits"])
            self._view_boundary_rows.inc(vstats["boundary_rows"])
        if miss_j and self.failure_detector is not None:
            # one latency sample per executed group — cache hits are
            # not operations the node performed
            self.failure_detector.record(node.node_id, wall)
        per_q_wall = wall / len(miss_j) if miss_j else 0.0
        scans: list[ScanResult | None] = [None] * len(group)
        walls = [0.0] * len(group)
        # read the hits out BEFORE storing misses: a store can FIFO-evict
        # a key that was a hit when hit_j was computed
        for j in hit_j:
            scans[j] = cache[ckeys[j]]
        for j, sr in zip(miss_j, miss_scans):
            scans[j] = sr
            walls[j] = per_q_wall
            if cache is not None:
                self._cache_store((cf.name, r.replica_id), cache, ckeys[j], sr)
        if cache is not None:
            self._cache_hits.inc(len(hit_j))
            self._cache_misses.inc(len(miss_j))
        return scans, walls  # type: ignore[return-value]

    def _execute_group(
        self,
        cf: ColumnFamily,
        r: ReplicaHandle,
        qidx: list[int],
        queries: list[Query],
        est_rows: np.ndarray,
        est_costs: np.ndarray,
        results: list,
        reports: list,
        *,
        hedged: bool,
        trace=None,
        retry: bool = False,
    ) -> None:
        """Run one replica's query group via ``execute_many``; measured
        wall time (× node slowdown) is split evenly across the queries
        that actually executed — result-cache hits are served at zero
        attributed wall. Hedged runs only replace a query's primary
        result when at least as fast (ties — e.g. both served from
        cache at zero wall — go to the hedge: the duplicate answered
        first or simultaneously, which is what ``hedged`` reports)."""
        group = [queries[i] for i in qidx]
        g = (
            trace.child(
                "engine.group_scan", replica=r.replica_id, node=r.node_id,
                queries=len(qidx), hedged=hedged, retry=retry,
            )
            if trace is not None
            else None
        )
        try:
            scans, walls = self._scan_with_cache(cf, r, group, trace=g)
        except TransientFault as e:
            if g is not None:
                g.end(error=type(e).__name__)
            raise
        if g is not None:
            g.end(rows=int(sum(sr.rows_scanned for sr in scans)))
        for j, i in enumerate(qidx):
            sr = scans[j]
            if hedged and not (
                reports[i] is None or walls[j] <= reports[i].wall_seconds
            ):
                continue
            results[i] = sr
            reports[i] = ReadReport(
                replica_id=r.replica_id,
                node_id=r.node_id,
                estimated_rows=float(est_rows[i]),
                estimated_cost=float(est_costs[i]),
                wall_seconds=walls[j],
                rows_scanned=sr.rows_scanned,
                hedged=hedged,
            )

    # -- tunable consistency (digest reads + read repair) ---------------------

    @staticmethod
    def _consistency_k(consistency: str, rf: int) -> int:
        """Replicas that must answer at a consistency level (read k)."""
        if consistency == ONE:
            return 1
        if consistency == QUORUM:
            return rf // 2 + 1
        if consistency == ALL:
            return rf
        raise ValueError(
            f"unknown consistency {consistency!r} "
            f"(expected one of {CONSISTENCY_LEVELS})"
        )

    def _consistency_pass(
        self,
        cf: ColumnFamily,
        part: Partition,
        live: list[ReplicaHandle],
        order: np.ndarray,
        picks: np.ndarray,
        qidx: list[int],
        queries: list[Query],
        results: list,
        reports: list,
        consistency: str,
        *,
        deadline_at: float | None = None,
        budget_s: float | None = None,
        trace=None,
    ) -> None:
        """Digest reads: execute each query on the next cost-ranked
        replicas until k distinct replicas (primary included) answered,
        compare the layout-independent digests, and on mismatch repair
        divergent replicas from the partition log. Majority digest wins
        (the returned result is re-pointed at a majority replica when
        the primary was the outlier); with no majority — e.g. a 1–1
        split at k = 2 — the log is the ground truth: every consulted
        replica is rebuilt and the query re-executes on the primary."""
        k = self._consistency_k(consistency, len(part.replicas))
        if k <= 1:
            return
        if len(live) < k:
            raise RuntimeError(
                f"consistency {consistency} needs {k} live replicas of "
                f"partition {part.partition_id} of {cf.name!r}, "
                f"have {len(live)}"
            )
        dg = (
            trace.child("engine.digest", level=consistency, k=k)
            if trace is not None
            else None
        )
        col_of = {qi: j for j, qi in enumerate(qidx)}
        row_of_rid = {r.replica_id: i for i, r in enumerate(live)}
        # alternates: per query the k-1 cheapest ranked replicas other
        # than the one that served the primary (hedging may have moved
        # it off picks[j])
        consulted: dict[int, set[int]] = {}
        alt_groups: dict[int, list[int]] = {}
        for j, qi in enumerate(qidx):
            primary_row = row_of_rid.get(reports[qi].replica_id)
            consulted[qi] = {primary_row} if primary_row is not None else set()
            chosen: list[int] = []
            for x in order[:, j]:
                x = int(x)
                if x in consulted[qi]:
                    continue
                chosen.append(x)
                if len(chosen) >= k - 1:
                    break
            for x in chosen:
                alt_groups.setdefault(x, []).append(qi)
        # execute the digest reads, failing over like the primary pass
        alt_scans: dict[int, list[tuple[ReplicaHandle, ScanResult]]] = {}
        queue = list(alt_groups.items())
        while queue:
            # digest reads are REQUIRED work at QUORUM/ALL — a spent
            # budget sheds the whole call rather than quietly answering
            # at a weaker level than the caller asked for
            self._check_deadline(deadline_at, budget_s)
            x, sub = queue.pop(0)
            for qi in sub:
                consulted[qi].add(x)
            try:
                scans, _walls = self._scan_with_cache(
                    cf, live[x], [queries[qi] for qi in sub], trace=dg
                )
            except TransientFault:
                self._read_retries.inc(len(sub))
                retry: dict[int, list[int]] = {}
                for qi in sub:
                    nxt = next(
                        (
                            int(y)
                            for y in order[:, col_of[qi]]
                            if int(y) not in consulted[qi]
                        ),
                        None,
                    )
                    if nxt is None:
                        raise RuntimeError(
                            f"consistency {consistency}: fewer than {k} live "
                            f"replicas answered for {cf.name!r}"
                        )
                    retry.setdefault(nxt, []).append(qi)
                queue.extend(retry.items())
                continue
            for qi, sr in zip(sub, scans):
                alt_scans.setdefault(qi, []).append((live[x], sr))

        repaired: set[int] = set()  # replica ids healed earlier in this pass

        def _fresh(h: ReplicaHandle, qi: int, sr: ScanResult) -> ScanResult:
            # a scan taken before this pass repaired its replica is
            # stale evidence — re-read (the repair invalidated the cache)
            if h.replica_id not in repaired:
                return sr
            return self._scan_with_cache(cf, h, [queries[qi]], trace=dg)[0][0]

        handle_of_rid = {r.replica_id: r for r in part.replicas}
        for qi in qidx:
            alts = alt_scans.get(qi)
            if not alts:
                continue
            prim = handle_of_rid[reports[qi].replica_id]
            entries = [(prim, _fresh(prim, qi, results[qi]))] + [
                (h, _fresh(h, qi, sr)) for h, sr in alts
            ]
            digs = [
                _result_digest(sr, self._table(cf, h), cf.key_names, cf.schema)
                for h, sr in entries
            ]
            if len(set(digs)) == 1:
                if entries[0][1] is not results[qi]:
                    results[qi] = entries[0][1]  # refreshed primary
                continue
            self._digest_mismatches.inc()
            counts: dict[int, int] = {}
            for d in digs:
                counts[d] = counts.get(d, 0) + 1
            best_d, best_n = max(counts.items(), key=lambda t: t[1])
            if best_n * 2 > len(digs):
                # majority wins: heal the minority from the log and
                # answer from a majority replica
                for (h, _sr), d in zip(entries, digs):
                    if d != best_d:
                        rp = (
                            dg.child("engine.read_repair", replica=h.replica_id)
                            if dg is not None
                            else None
                        )
                        self._repair_replica(cf, part, h)
                        repaired.add(h.replica_id)
                        self._read_repairs.inc()
                        if rp is not None:
                            rp.end()
                win, win_scan = next(
                    e for e, d in zip(entries, digs) if d == best_d
                )
                results[qi] = win_scan
                reports[qi] = dataclasses.replace(
                    reports[qi],
                    replica_id=win.replica_id,
                    node_id=win.node_id,
                    rows_scanned=win_scan.rows_scanned,
                )
            else:
                # no majority: rebuild every consulted replica from the
                # log (the ground truth) and re-execute on the primary
                for h, _sr in entries:
                    rp = (
                        dg.child("engine.read_repair", replica=h.replica_id)
                        if dg is not None
                        else None
                    )
                    self._repair_replica(cf, part, h)
                    repaired.add(h.replica_id)
                    self._read_repairs.inc()
                    if rp is not None:
                        rp.end()
                scan = self._scan_with_cache(cf, prim, [queries[qi]], trace=dg)[0][0]
                results[qi] = scan
                reports[qi] = dataclasses.replace(
                    reports[qi], rows_scanned=scan.rows_scanned
                )
        if dg is not None:
            dg.end()

    def _hedge_groups(
        self,
        live: list[ReplicaHandle],
        order: np.ndarray,
        picks: np.ndarray,
        qidx: list[int],
        hedge_ratio: float,
    ) -> dict[int, list[int]]:
        """Queries whose picked node is a straggler (slowdown >
        ``hedge_ratio``), grouped by the next-cheapest replica on a
        *different* node — the same alternate a scalar ``read`` hedges
        to. Shared by both planners; ``qidx[j]`` is the global query
        index of scheduler column ``j``."""
        groups: dict[int, list[int]] = {}
        for j, qi in enumerate(qidx):
            pick_node = live[int(picks[j])].node_id
            if self.nodes[pick_node].slowdown <= hedge_ratio:
                continue
            alt = next(
                (int(k) for k in order[:, j] if live[int(k)].node_id != pick_node),
                -1,
            )
            if alt >= 0:
                groups.setdefault(alt, []).append(qi)
        return groups

    # -- partitioned scatter-gather read path ---------------------------------

    def _partition_row_offsets(self, cf: ColumnFamily) -> np.ndarray:
        """Global row offset of each partition in the cross-partition
        select index space (partitions concatenated in ring order).
        Built from the partition logs' row counts — append-only system,
        so log rows == table rows for any fully-flushed live replica —
        which keeps the offsets independent of staging state."""
        rows = np.array(
            [part.n_rows_committed for part in cf.partitions], dtype=np.int64
        )
        offsets = np.zeros(len(rows), dtype=np.int64)
        np.cumsum(rows[:-1], out=offsets[1:])
        return offsets

    def _read_many_partitioned(
        self,
        cf: ColumnFamily,
        queries: list[Query],
        *,
        hedge: bool,
        hedge_ratio: float,
        consistency: str = ONE,
        deadline_at: float | None = None,
        budget_s: float | None = None,
        trace=None,
    ) -> list[tuple[ScanResult, ReadReport]]:
        """Scatter-gather ``read_many`` over a partitioned column family.

        **Scatter** (host, pure arithmetic): each query's canonical slab
        bounds — the ``slab_bounds_many`` walk over ``key_names``, the
        same packing the ring's tokens use — are intersected with the
        ring's contiguous token ranges, giving a contiguous partition
        span per query (an equality filter on the leading canonical key
        pins one partition; an open scan fans out to all). A
        ``(partition, query)`` pair whose slab is disjoint from the
        partition's observed committed-token range
        (``Partition.may_contain`` — append-only writes keep the
        extrema monotone, so the test is never stale) is dropped before
        grouping: no device launch and no result-cache probe for a
        partition that provably contributes zero rows. Per surviving
        partition the Cost Evaluator ranks that partition's *live*
        replicas with the partition's own ``TableStats`` (its slice's
        selectivities — the CF-global stats are only the fallback), the
        RR tie-break draws from the partition's own counter, and each
        ``(partition, replica)`` group runs the ordinary grouped
        execution — device-resident partitions answer with the fused
        locate+scan launch, and the per-replica result cache applies
        per partition replica.

        **Gather** (host): per query, sum/count partial aggregates add
        up across its partitions in ring order, and select indices
        concatenate after each partition's local row indices (already
        host-ordered via the table's ``row_map``) are offset into the
        global index space — partitions in ring order, each in its
        chosen replica's serialization order (``merge_partial_scans``).
        The merged report carries the first executing partition's
        routing choice and the summed wall/rows_scanned; a query all of
        whose partitions were skipped gets a synthetic empty result and
        a placeholder report (``replica_id == node_id == -1`` — no
        replica was consulted).
        """
        n_q = len(queries)
        ring = cf.ring
        sc = trace.child("engine.scatter") if trace is not None else None
        bounds = slab_bounds_many(queries, cf.key_names, cf.schema)
        p_lo, p_hi = ring.span_partitions(bounds)

        touched: dict[int, list[int]] = {}
        for qi in range(n_q):
            for pid in range(int(p_lo[qi]), int(p_hi[qi]) + 1):
                part = cf.partitions[pid]
                if not part.may_contain(int(bounds[qi, 0]), int(bounds[qi, 1])):
                    self._empty_partition_skips.inc()
                    continue
                touched.setdefault(pid, []).append(qi)
        if sc is not None:
            sc.end(partitions=len(touched))

        rf = cf.replication_factor
        n_slots = len(cf.slot_layouts)
        partials: dict[int, tuple[list, list]] = {}
        for pid in sorted(touched):
            self._check_deadline(deadline_at, budget_s)
            ps = (
                trace.child("engine.partition", partition=pid)
                if trace is not None
                else None
            )
            part = cf.partitions[pid]
            qidx = touched[pid]
            live = [r for r in part.replicas if self.nodes[r.node_id].alive]
            if not live:
                raise RuntimeError(
                    f"no live replica for partition {pid} of {cf.name!r}"
                )
            # this partition's replica ranking, from ITS OWN stats: the
            # same vectorized Eq 1-2 as the single-partition path, but
            # the selectivities describe the partition's row slice
            group = [queries[i] for i in qidx]
            rows_sub, cost_sub = cf.cost_model.rank_matrices(
                cf.slot_layouts, group, stats=part.stats
            )
            # view term: same cap as the single-partition planner, per
            # (slot layout, group query); the any() guard keeps
            # all-select batches off the eligibility arrays
            if cf.views and any(q.agg in VIEW_AGGS for q in group):
                elig = view_eligible_matrix(cf.slot_layouts, group)
                if elig.any():
                    capped = np.minimum(rows_sub, float(VIEW_ROWS_CAP))
                    cost_sub = np.where(
                        elig,
                        np.stack(
                            [
                                cf.cost_model.cost_fn(len(lay)).many(capped[s])
                                for s, lay in enumerate(cf.slot_layouts)
                            ]
                        ),
                        cost_sub,
                    )
            # scatter the group estimates back to full batch width —
            # _execute_group indexes them by global query index
            rows_mat = np.zeros((n_slots, n_q))
            cost_mat = np.zeros((n_slots, n_q))
            rows_mat[:, qidx] = rows_sub
            cost_mat[:, qidx] = cost_sub
            slots = [r.replica_id - part.vnode_id * rf for r in live]
            factors = self._live_cost_factors(live)
            if factors is not None:
                # penalize suspected nodes' rows in place so ranking,
                # failover order and reported est_cost all agree
                for k, s in enumerate(slots):
                    cost_mat[s] *= factors[k]
            sub_cost = cost_mat[np.asarray(slots)][:, qidx]  # (live, group)
            order, picks = _schedule_picks(sub_cost, part.rr_counter)

            res_p: list[ScanResult | None] = [None] * n_q
            rep_p: list[ReadReport | None] = [None] * n_q
            rows_live = rows_mat[np.asarray(slots)]
            cost_live = cost_mat[np.asarray(slots)]
            self._run_groups(
                cf, live, order, picks, qidx, queries, rows_live, cost_live,
                res_p, rep_p, deadline_at=deadline_at, budget_s=budget_s,
                trace=ps,
            )
            if hedge and len(live) > 1 and not _deadline_spent(deadline_at):
                for k, sub in self._hedge_groups(
                    live, order, picks, qidx, hedge_ratio
                ).items():
                    try:
                        self._execute_group(
                            cf, live[k], sub, queries, rows_live[k],
                            cost_live[k], res_p, rep_p, hedged=True, trace=ps,
                        )
                    except TransientFault:
                        continue  # best-effort duplicate
            if consistency != ONE:
                self._consistency_pass(
                    cf, part, live, order, picks, qidx, queries,
                    res_p, rep_p, consistency,
                    deadline_at=deadline_at, budget_s=budget_s, trace=ps,
                )
            if ps is not None:
                ps.end()
            partials[pid] = (res_p, rep_p)

        # gather: merge each query's per-partition partials in ring order
        ga = trace.child("engine.gather") if trace is not None else None
        offsets = self._partition_row_offsets(cf)
        out: list[tuple[ScanResult, ReadReport]] = []
        for qi in range(n_q):
            scans = []
            reps: list[ReadReport] = []
            for pid in range(int(p_lo[qi]), int(p_hi[qi]) + 1):
                if pid not in partials or partials[pid][0][qi] is None:
                    continue  # skipped: provably no rows in this slab
                scans.append((partials[pid][0][qi], int(offsets[pid])))
                reps.append(partials[pid][1][qi])
            if not scans:
                # every candidate partition was skipped — the query
                # provably matches nothing; synthesize the empty result
                # without consulting any replica
                empty_sel = (
                    np.empty(0, dtype=np.int64)
                    if queries[qi].agg == "select"
                    else None
                )
                out.append(
                    (
                        ScanResult(0.0, 0, 0, empty_sel),
                        ReadReport(
                            replica_id=-1,
                            node_id=-1,
                            estimated_rows=0.0,
                            estimated_cost=0.0,
                            wall_seconds=0.0,
                            rows_scanned=0,
                        ),
                    )
                )
                continue
            merged = merge_partial_scans(scans, queries[qi].agg)
            first = reps[0]
            out.append(
                (
                    merged,
                    ReadReport(
                        replica_id=first.replica_id,
                        node_id=first.node_id,
                        estimated_rows=first.estimated_rows,
                        estimated_cost=first.estimated_cost,
                        wall_seconds=sum(r.wall_seconds for r in reps),
                        rows_scanned=merged.rows_scanned,
                        hedged=any(r.hedged for r in reps),
                    ),
                )
            )
        if ga is not None:
            ga.end()
        return out

    # -- ring migration (vnode split / merge / rebalance) ---------------------

    def partition_imbalance(self, cf_name: str) -> float:
        """Max/mean committed-row imbalance across the ring (1.0 =
        perfectly balanced). The exact form of the histogram drift
        signal — ``rebalance`` reports it before/after."""
        cf = self.column_families[cf_name]
        rows = np.array(
            [p.n_rows_committed for p in cf.partitions], dtype=np.float64
        )
        total = rows.sum()
        if total <= 0:
            return 1.0
        return float(rows.max() / (total / rows.size))

    def split_partition(
        self, cf_name: str, partition_id: int, token: int | None = None
    ) -> int:
        """Online split: cut one partition's token range in two at
        ``token`` (rows with canonical token ≥ ``token`` move to the
        right child). Default cut: the partition's median committed
        token — the boundary that halves its *rows*, not its key range.
        Both children are new vnodes built by replaying token-sliced
        copies of the parent's commit log (see ``_reshard``); every
        other partition is untouched. Returns the cut token."""
        cf = self.column_families[cf_name]
        part = cf.partitions[partition_id]
        if token is None:
            kc, _ = part.commitlog.replay_columns()
            toks = np.sort(pack_columns(kc, cf.key_names, cf.schema))
            if toks.size:
                token = int(toks[toks.size // 2])
            else:
                token = (part.token_lo + part.token_hi + 1) // 2
            # a median equal to the range start cannot form a boundary
            # (the left child would own nothing of the cut); nudge right
            token = max(token, part.token_lo + 1)
        token = int(token)
        if not part.token_lo < token <= part.token_hi:
            raise ValueError(
                f"split token {token} outside partition {partition_id}'s "
                f"range ({part.token_lo}, {part.token_hi}]"
            )
        self._reshard(cf, sorted(cf.ring.starts + (token,)))
        return token

    def merge_partitions(self, cf_name: str, partition_id: int) -> None:
        """Online merge: fuse ring-adjacent partitions ``partition_id``
        and ``partition_id + 1`` into one new vnode whose commit log is
        the two logs concatenated in ring order (see ``_reshard``).
        Every other partition is untouched."""
        cf = self.column_families[cf_name]
        if partition_id + 1 >= cf.ring.n_partitions:
            raise ValueError(
                f"partition {partition_id} has no right neighbor to merge with"
            )
        starts = list(cf.ring.starts)
        del starts[partition_id + 1]
        self._reshard(cf, starts)

    def rebalance(
        self,
        cf_name: str,
        *,
        partitions: int | None = None,
        exact: bool = True,
    ) -> dict:
        """Load-aware rebalancing: move the ring boundaries to the
        observed row-count quantiles, so each partition owns ~1/P of
        the committed rows (Cassandra's vnode reassignment, done as one
        ring-wide reshard). ``partitions`` changes the partition count
        (default: keep P). ``exact=True`` (default) takes quantiles of
        the exact committed tokens replayed from the partition logs —
        what the ≤ 1.25× imbalance target needs; ``exact=False`` uses
        the column family's token *histogram* proposal instead (cheaper,
        resolution = one histogram bin). Partitions whose range is
        unchanged keep all state; the rest migrate by log slicing +
        replay (``_reshard``). Returns an info dict with the imbalance
        before/after and the rows moved. No-op (zero rows moved) when
        the boundaries come out unchanged.

        The engine's ``rebalance_imbalance`` knob arms an automatic
        form: after a write-path flush, a P > 1 column family whose
        histogram drift exceeds the threshold rebalances itself.
        """
        cf = self.column_families[cf_name]
        P = cf.ring.n_partitions if partitions is None else int(partitions)
        before = self.partition_imbalance(cf_name)
        if exact or cf.token_hist is None:
            toks = np.concatenate(
                [
                    pack_columns(
                        p.commitlog.replay_columns()[0], cf.key_names, cf.schema
                    )
                    for p in cf.partitions
                ]
            )
            new_ring = TokenRing.from_tokens(cf.schema, cf.key_names, toks, P)
        else:
            new_ring = TokenRing.from_histogram(
                cf.schema, cf.key_names, cf.token_hist, P
            )
        moved = 0
        if new_ring.starts != cf.ring.starts:
            moved = self._reshard(cf, new_ring.starts)
        return {
            "partitions": P,
            "imbalance_before": before,
            "imbalance_after": self.partition_imbalance(cf_name),
            "rows_moved": moved,
        }

    def _reshard(self, cf: ColumnFamily, new_starts: Sequence[int]) -> int:
        """Rebuild the ring around new boundaries; returns rows moved.

        The migration contract (documented in ``repro.core.ring``):

        * a partition whose inclusive ``[lo, hi]`` range appears
          unchanged in the new ring is KEPT — same vnode, same log,
          same tables, memtables, stats, caches and RR counter; only
          its ``partition_id`` (ring position) is renumbered;
        * every other new range becomes a fresh vnode whose commit log
          is the token-sliced concatenation (ring order, fresh LSNs) of
          the overlapping old partitions' logs, and whose replica
          tables are built by replaying that log — the exact
          ``recover_node(source="log")`` path, so post-migration
          log-replay recovery is bit-identical to a surviving peer by
          construction. Staged-but-unflushed rows ride along for free:
          they are already log records, so the replay includes them and
          the fresh memtables start empty;
        * only migrated replica ids lose node tables and result-cache
          entries; a replica placed on a dead node is simply not
          installed (``recover_node`` rebuilds it from the new log).

        Counters: every boundary present in the new ring but not the
        old is a split, every boundary dropped is a merge, and the
        committed rows of all rebuilt partitions count as moved.
        """
        new_ring = cf.ring.with_starts(new_starts)
        old_parts = list(cf.partitions)
        old_ranges = [(p.token_lo, p.token_hi) for p in old_parts]
        old_by_range = dict(zip(old_ranges, old_parts))
        new_ranges = [
            new_ring.token_range(pid) for pid in range(new_ring.n_partitions)
        ]
        rf = cf.replication_factor

        new_parts: list[Partition] = []
        rows_moved = 0
        for pid, (nlo, nhi) in enumerate(new_ranges):
            kept = old_by_range.get((nlo, nhi))
            if kept is not None:
                kept.partition_id = pid
                for r in kept.replicas:
                    r.partition_id = pid
                new_parts.append(kept)
                continue
            overlap = [
                p
                for p, (olo, ohi) in zip(old_parts, old_ranges)
                if not (ohi < nlo or olo > nhi)
            ]

            def in_range(kc, _lo=nlo, _hi=nhi):
                t = pack_columns(kc, cf.key_names, cf.schema)
                return (t >= _lo) & (t <= _hi)

            log = CommitLog.concatenated(
                [p.commitlog.sliced(in_range) for p in overlap]
            )
            kc, vc = log.replay_columns()
            toks = pack_columns(kc, cf.key_names, cf.schema)
            # stats: pure-union merges add histograms bin-wise (exact —
            # disjoint row sets); a range cut inside an old partition
            # recomputes from the replayed slice
            if (
                len(overlap) > 1
                and overlap[0].token_lo == nlo
                and overlap[-1].token_hi == nhi
                and all(p.stats is not None for p in overlap)
            ):
                stats_p = overlap[0].stats
                for p in overlap[1:]:
                    stats_p = stats_p.merged_with(p.stats)
            else:
                stats_p = TableStats.from_columns(kc, cf.schema)
            vnode = cf.next_vnode
            cf.next_vnode += 1
            handles: list[ReplicaHandle] = []
            memtables: dict[int, Memtable] = {}
            flushed_lsn: dict[int, int] = {}
            for slot, layout in enumerate(cf.slot_layouts):
                rid = vnode * rf + slot
                node_id = self._place(rid, cf.name)
                if self.nodes[node_id].alive:
                    table = SortedTable.from_columns(kc, vc, layout, cf.schema)
                    if cf.device_resident:
                        table.place_on_device()
                    # a resharded vnode's views are re-derived over its
                    # sliced rows; untouched (kept) vnodes keep theirs
                    self._ensure_views(cf, table)
                    if self.checksums:
                        table.seal_checksum()
                    self.nodes[node_id].tables[(cf.name, rid)] = table
                    # rebuilt from the new log's full replay, so the
                    # watermark starts at its tail; replicas on dead
                    # nodes get theirs when recovery installs them
                    flushed_lsn[rid] = log.next_lsn
                handles.append(
                    ReplicaHandle(rid, tuple(layout), node_id, partition_id=pid)
                )
                memtables[rid] = Memtable(
                    layout, cf.schema, cf.key_names, cf.value_names
                )
            part = Partition(
                partition_id=pid,
                token_lo=nlo,
                token_hi=nhi,
                replicas=handles,
                commitlog=log,
                memtables=memtables,
                compaction=overlap[0].compaction if overlap else cf.compaction,
                vnode_id=vnode,
                stats=stats_p,
                flushed_lsn=flushed_lsn,
            )
            part.observe_tokens(toks)
            new_parts.append(part)
            rows_moved += log.n_rows

        # retire the migrated old partitions: their replica ids vanish,
        # so their node tables and result-cache entries (ONLY theirs —
        # kept partitions' caches stay warm) go with them
        kept_ids = {id(p) for p in new_parts}
        for part in old_parts:
            if id(part) in kept_ids:
                continue
            for r in part.replicas:
                self.nodes[r.node_id].tables.pop((cf.name, r.replica_id), None)
                self._result_cache.pop((cf.name, r.replica_id), None)
                self._cache_sel_bytes.pop((cf.name, r.replica_id), None)
            part.memtables.clear()

        old_set = set(cf.ring.starts)
        new_set = set(new_ring.starts)
        self._partition_splits.inc(len(new_set - old_set))
        self._partition_merges.inc(len(old_set - new_set))
        self._rebalance_rows_moved.inc(rows_moved)
        cf.ring = new_ring
        cf.partitions = new_parts
        return rows_moved

    # -- Write Scheduler (commit log → memtable → sorted runs) ----------------

    def write(
        self,
        cf_name: str,
        key_cols: Mapping[str, np.ndarray],
        value_cols: Mapping[str, np.ndarray],
        *,
        parallel: bool | None = None,
        flush: bool | None = None,
        trace=None,
    ) -> float:
        """Commit a batch write through the durable path and refresh
        stats; returns wall seconds. The batch is (1) appended to the
        column family's shared commit log — the layout-agnostic
        durability record any replica can be rebuilt from — then (2)
        staged into each live replica's memtable, and (3) flushed as one
        sorted run per replica when the staging threshold is reached
        (``memtable_rows``; 0 = write-through, so every write flushes).
        ``flush`` forces (True) or defers (False) step 3 explicitly.
        Matches §5.3: per-replica flush cost is one sort regardless of
        layout, so HR writes cost the same as TR (Table 1).

        *Group commit falls out of the staging*: with a threshold set, g
        writes of b rows flush as one sort + one merge of g×b rows —
        the amortization ``benchmarks/write_queue.py`` measures. The
        per-replica flushes remain independent and ``parallel=True``
        (default: the engine's ``parallel_writes`` flag) overlaps them
        on a thread pool; the merge hot path now runs through
        GIL-releasing ``np.sort`` + scatters (``SortedTable.merge_run``),
        and the same benchmark re-measures the overlap honestly.

        Deferred rows are never stale-served: reads flush a replica's
        pending rows (invalidating its cached results) before touching
        it. On a device-resident column family each flush *appends* its
        run to the replica's resident arrays and the column family's
        ``CompactionPolicy`` collapses the run stack on device once it
        outgrows the base — nothing is re-uploaded either way.

        On a partitioned column family the batch is first split by the
        token ring (one vectorized pack + partition lookup): each owning
        partition's sub-batch becomes one record in *that partition's*
        commit log and stages into that partition's live replicas only —
        a node hosting no replica of a row's partition never sees the
        row.
        """
        cf = self.column_families[cf_name]
        if parallel is None:
            parallel = self.parallel_writes
        t0 = time.perf_counter()
        w = (
            trace.child(
                "engine.write", cf=cf_name,
                rows=int(len(next(iter(key_cols.values())))) if key_cols else 0,
            )
            if trace is not None
            else None
        )
        if cf.ring.n_partitions == 1:
            routed = [(cf.partitions[0], key_cols, value_cols, None)]
        else:
            kc_arr = {c: np.asarray(key_cols[c]) for c in cf.key_names}
            tokens = cf.ring.tokens(kc_arr, cf.schema)
            pids = cf.ring.partition_of_tokens(tokens)
            if cf.token_hist is not None:
                cf.token_hist.add_tokens(tokens, device=cf.device_resident)
            routed = []
            for pid in np.unique(pids):
                mask = pids == pid
                routed.append(
                    (
                        cf.partitions[int(pid)],
                        {c: kc_arr[c][mask] for c in cf.key_names},
                        {
                            c: np.asarray(value_cols[c])[mask]
                            for c in cf.value_names
                        },
                        tokens[mask],
                    )
                )
        # missed writes on dead nodes are repaired by Recovery (the log
        # has every record; dead replicas neither stage nor flush). The
        # record's columns are the log's own immutable copies, so every
        # memtable stages them by reference — one copy per write, not RF.
        # A dead replica with an open hint just grows its hinted tail —
        # the hint is an LSN watermark into this same log, never a copy
        la = w.child("engine.log_append") if w is not None else None
        recs = []
        for part, kc_p, _vc_p, _toks_p in routed:
            part.commitlog.append(kc_p, _vc_p)
            recs.append(part.commitlog.tail)
        if la is not None:
            la.end(partitions=len(routed))
        ms = w.child("engine.memtable_stage") if w is not None else None
        for (part, kc_p, vc_p, toks_p), rec in zip(routed, recs):
            for r in part.replicas:
                if self.nodes[r.node_id].alive:
                    part.memtables[r.replica_id].stage(
                        rec.key_cols, rec.value_cols, copy=False
                    )
                elif r.replica_id in part.hints:
                    self._hints_queued.inc()
            if toks_p is not None:
                part.observe_tokens(toks_p)
            if part.stats is not None:
                # incremental per-partition selectivities: the routed
                # sub-batch folds into exactly the partition it joined
                part.stats.merge_rows(rec.key_cols, device=cf.device_resident)
        cf.stats.merge_rows(key_cols, device=cf.device_resident)
        if ms is not None:
            ms.end()
        # the threshold check spans ALL live replicas, not just this
        # write's routed partitions: rows staged earlier in a partition
        # the current key mix never touches again must still flush once
        # over the group-commit threshold
        live = [r for r in cf.replicas if self.nodes[r.node_id].alive]
        if flush is None:
            flush = cf.memtable_rows <= 0 or any(
                self._memtable(cf, r).n_staged >= cf.memtable_rows
                for r in live
            )
        if flush:
            self._flush_replicas(cf, live, parallel=parallel, trace=w)
            # skew-drift trigger: when the observed-token histogram says
            # one partition's row mass drifted past the threshold × mean,
            # rebalance in place (boundaries to observed quantiles).
            # Post-flush only — migration replays logs, so rebalancing a
            # freshly flushed CF never races staged state
            if (
                self.rebalance_imbalance > 0
                and cf.ring.n_partitions > 1
                and cf.token_hist is not None
                and cf.token_hist.imbalance(cf.ring.starts)
                > self.rebalance_imbalance
            ):
                self.rebalance(cf_name)
        if w is not None:
            w.end()
        return time.perf_counter() - t0

    def _flush_replicas(
        self, cf: ColumnFamily, replicas: Sequence[ReplicaHandle], *,
        parallel: bool = False, trace=None,
    ) -> None:
        """Flush the given replicas' staged rows: one sorted run per
        replica (in its own layout), merged via ``merge_run``, result
        cache invalidated, then the compaction policy applied to the
        merged table. ``parallel`` overlaps the independent per-replica
        merges on a thread pool (``engine.flush`` spans are emitted per
        replica either way; CPython's atomic int/list ops keep the
        shared tracer consistent under the pool)."""
        pending = [
            r
            for r in replicas
            if self.nodes[r.node_id].alive and self._memtable(cf, r).n_staged
        ]
        if not pending:
            return
        t0 = time.perf_counter()

        def _flush(r: ReplicaHandle) -> tuple[ReplicaHandle, SortedTable]:
            # peek, don't drain: the memtable is cleared only after the
            # merged table is installed below, so an exception here (or
            # in a sibling thread) never loses committed rows — the
            # staged buffers and the old table both survive a retry
            node = self.nodes[r.node_id]
            fs = (
                trace.child(
                    "engine.flush", replica=r.replica_id, node=r.node_id,
                    rows=int(self._memtable(cf, r).n_staged),
                )
                if trace is not None
                else None
            )
            try:
                if node.flush_fault_budget > 0:
                    node.flush_fault_budget -= 1
                    if self.failure_detector is not None:
                        self.failure_detector.record_failure(node.node_id)
                    self._flush_faults.inc()
                    raise TransientFlushError(node.node_id)
                run = self._memtable(cf, r).peek_run()
                if self.checksums and not run.verify():
                    self._corrupt_runs.inc()
                    raise CorruptRunError(
                        f"flush of {cf.name!r} replica {r.replica_id}: sorted "
                        f"run failed its checksum"
                    )
                table = node.tables[(cf.name, r.replica_id)]
                fm = fs.child("engine.flush_merge") if fs is not None else None
                merged = table.merge_run(run, trace=fm)
                if fm is not None:
                    fm.end()
                if self.checksums:
                    # extend the seal with the run's digest — O(run), and
                    # derived from durable history, never from the (possibly
                    # corrupted) base arrays: a bit flip in the base stays
                    # detectable by scrub after any number of flushes
                    if table.stored_digest is not None:
                        merged.stored_digest = combine_digests(
                            table.stored_digest, run.digest
                        )
                    else:
                        merged.seal_checksum()
            except Exception as e:
                if fs is not None:
                    fs.end(error=type(e).__name__)
                raise
            if fs is not None:
                fs.end()
            return r, merged

        if parallel and len(pending) > 1:
            merged_tables = list(self._executor.map(_flush, pending))
        else:
            merged_tables = [_flush(r) for r in pending]
        for r, merged in merged_tables:
            if cf.device_resident and not merged.device_resident:
                merged.place_on_device()
            self._ensure_views(cf, merged)
            self.nodes[r.node_id].tables[(cf.name, r.replica_id)] = merged
            self._memtable(cf, r).clear()
            self._flushes.inc()
            part = cf.partitions[r.partition_id]
            if part.commitlog is not None:
                # hinted-handoff watermark: this replica's table now
                # reflects every log record below the tail
                part.flushed_lsn[r.replica_id] = part.commitlog.next_lsn
            self._invalidate_result_cache(cf.name, replica_id=r.replica_id)
            policy = part.compaction
            if policy is not None:
                tc = trace.tracer.now() if trace is not None else 0.0
                if compact_table(merged, policy):
                    # content unchanged by compaction, so the sealed
                    # multiset digest carries over as-is
                    self._compactions.inc()
                    if merged.has_views:
                        # compact_runs re-derived the per-block partials
                        # over the collapsed run stack (full rebuild —
                        # block boundaries moved with the row order)
                        self._view_rebuilds.inc()
                    self._invalidate_result_cache(cf.name, replica_id=r.replica_id)
                    if trace is not None:
                        # retroactive span: only compactions that ran
                        # appear in the tree, with an honest wall
                        trace.child(
                            "engine.compaction", t=tc, replica=r.replica_id
                        ).end()
        # count-based auto-checkpoint: once a flushed partition's log
        # has accumulated more than the engine's record threshold since
        # its last snapshot AND the partition is fully drained (every
        # replica flushed through the tail — the documented safety
        # condition of CommitLog.checkpoint), collapse its history.
        # Deferred while any hint is open: a checkpoint re-LSNs the
        # record the hint watermark points into, forcing node_up onto
        # the full-rebuild fallback — cheaper to wait the outage out
        k = self.commitlog_checkpoint_records
        if k:
            for pid in sorted({r.partition_id for r, _ in merged_tables}):
                part = cf.partitions[pid]
                log = part.commitlog
                if (
                    log is not None
                    and log.should_checkpoint(k)
                    and not part.hints
                    and not any(mt.n_staged for mt in part.memtables.values())
                ):
                    log.checkpoint()
                    # every drained replica is flushed through the new
                    # snapshot record by construction
                    for rid in list(part.flushed_lsn):
                        part.flushed_lsn[rid] = log.next_lsn
                    self._auto_checkpoints.inc()
        self._flush_wall.inc(time.perf_counter() - t0)

    def _ensure_views(
        self, cf: ColumnFamily, table: SortedTable, *, count: bool = True
    ) -> None:
        """Materialize a views CF's per-block partials on ``table`` if
        absent (full rebuild from the resident arrays, counted under
        ``view_rebuilds`` unless ``count=False``).

        Views are *derived* state, so every site that rebuilds or
        replaces a replica table — flush fallback, migration reshard,
        log-replay recovery, node_up heal, scrub repair — funnels
        through here right where it already invalidates the result
        cache: the two caches share one invalidation discipline (stale
        content never outlives the table swap that produced it).
        Tables that already carry views (the incremental ``merge_run``
        extension, or ``compact_runs``' own rebuild) are left alone."""
        if not cf.views or table.has_views:
            return
        if not table.device_resident:
            table.place_on_device()
        table.build_views()
        if count:
            self._view_rebuilds.inc()

    def _memtable(self, cf: ColumnFamily, r: ReplicaHandle) -> Memtable:
        return cf.partitions[r.partition_id].memtables[r.replica_id]

    def _ensure_flushed(
        self, cf: ColumnFamily, r: ReplicaHandle, *, trace=None
    ) -> None:
        """Flush one replica's pending staged rows (read barrier)."""
        mt = cf.partitions[r.partition_id].memtables.get(r.replica_id)
        if mt is not None and mt.n_staged:
            if trace is None:
                self._flush_replicas(cf, [r])
            else:
                fb = trace.child("engine.flush_barrier", rows=int(mt.n_staged))
                self._flush_replicas(cf, [r], trace=fb)
                fb.end()

    def flush_memtables(self, cf_name: str, *, parallel: bool | None = None) -> None:
        """Drain every live replica's memtable (group-commit flush)."""
        cf = self.column_families[cf_name]
        if parallel is None:
            parallel = self.parallel_writes
        live = [r for r in cf.replicas if self.nodes[r.node_id].alive]
        self._flush_replicas(cf, live, parallel=parallel)

    def checkpoint_commitlog(self, cf_name: str) -> int:
        """Collapse every partition's commit log into one snapshot
        record, bounding log memory and replay-recovery cost at
        O(current rows) instead of O(rows ever written). Flushes every
        live replica first so no record still backs staged-only rows;
        log-replay recovery is unchanged (the snapshot replays to the
        identical dataset). Returns the highest snapshot LSN (the only
        one when ``partitions == 1``). The count-based automatic
        trigger (``commitlog_checkpoint_records``) fires the same
        collapse per partition after a flush."""
        cf = self.column_families[cf_name]
        self.flush_memtables(cf_name)
        top = 0
        for part in cf.partitions:
            top = max(top, part.commitlog.checkpoint())
            # every flushed replica is complete through the snapshot —
            # advance the hinted-handoff watermarks past it so a later
            # short outage still heals by tail replay
            for rid in list(part.flushed_lsn):
                part.flushed_lsn[rid] = part.commitlog.next_lsn
        return top

    # -- Recovery ----------------------------------------------------------------

    def fail_node(self, node_id: int, *, transient: bool = False) -> None:
        """Take a node down. The default models *node loss*: the node's
        disk (every partition replica it hosted, across all column
        families) and memtables are gone; partitions the node held no
        replica of are untouched; the per-partition commit logs are the
        durable copy ``recover_node`` rebuilds from.

        ``transient=True`` models a *short outage* (process restart,
        network partition): the replica tables survive on disk, only
        the staged memtable rows are lost — and those are already log
        records. Each hosted partition opens a **hint**: the replica's
        flushed-LSN watermark, recording exactly where its table's
        knowledge of the log ends. Writes committed during the outage
        just grow the log past the watermark; ``node_up`` replays only
        that tail (hinted handoff — O(missed writes), not O(dataset)).

        Failing a node that is already down is an explicit no-op — the
        first failure's hints keep their (older, still correct)
        watermarks. An out-of-range ``node_id`` raises ``ValueError``.
        """
        if not 0 <= node_id < len(self.nodes):
            raise ValueError(
                f"unknown node {node_id} (cluster has {len(self.nodes)})"
            )
        node = self.nodes[node_id]
        if not node.alive:
            return  # already down; earlier hints/loss state stands
        node.alive = False
        for cf_name, cf in self.column_families.items():
            for part in cf.partitions:
                for r in part.replicas:
                    if r.node_id != node_id:
                        continue
                    rid = r.replica_id
                    if transient:
                        if part.commitlog is not None:
                            # hint = LSN watermark into the shared log,
                            # never a data copy
                            part.hints[rid] = part.flushed_lsn.get(rid, 0)
                    else:
                        part.hints.pop(rid, None)
                        part.flushed_lsn.pop(rid, None)
                    if rid in part.memtables:
                        # the memtable dies with its node either way; the
                        # commit log is the durable copy every staged row
                        # replays from
                        part.memtables[rid].clear()
            self._invalidate_result_cache(cf_name, node_id=node_id)
        if not transient:
            node.tables = {}  # disk lost

    # -- replica rebuild/install helpers (recovery, read repair, scrub) ------

    def _rebuild_replica_table(
        self,
        cf: ColumnFamily,
        part: Partition,
        r: ReplicaHandle,
        *,
        source: str = "log",
    ) -> SortedTable:
        """Rebuild one partition replica's full table in its own layout:
        replay the owning partition's commit log (``source="log"``, the
        ground truth) or re-sort a surviving live peer
        (``source="survivor"``, also the fallback when the partition has
        no log)."""
        log = part.commitlog
        if source == "log" and log is not None and len(log):
            kc, vc = log.replay_columns()
            rebuilt = SortedTable.from_columns(kc, vc, r.layout, cf.schema)
        else:
            survivor = next(
                (
                    s
                    for s in part.replicas
                    if s.replica_id != r.replica_id
                    and self.nodes[s.node_id].alive
                    and (cf.name, s.replica_id) in self.nodes[s.node_id].tables
                ),
                None,
            )
            if survivor is None:
                raise RuntimeError(
                    f"data loss: no survivor for {cf.name!r} partition "
                    f"{part.partition_id} replica {r.replica_id}"
                )
            self._ensure_flushed(cf, survivor)  # staged rows too
            src = self.nodes[survivor.node_id].tables[
                (cf.name, survivor.replica_id)
            ]
            rebuilt = src.resorted(r.layout)
        if cf.device_resident:
            rebuilt.place_on_device()
        self._ensure_views(cf, rebuilt)
        if self.checksums:
            rebuilt.seal_checksum()
        return rebuilt

    def _install_rebuilt(
        self,
        cf: ColumnFamily,
        part: Partition,
        r: ReplicaHandle,
        table: SortedTable,
    ) -> None:
        """Install a fully rebuilt replica table: fresh memtable (a full
        rebuild IS flushed state), hint discharged, watermark at the log
        tail, stale cached results dropped."""
        rid = r.replica_id
        self.nodes[r.node_id].tables[(cf.name, rid)] = table
        part.memtables[rid] = Memtable(
            r.layout, cf.schema, cf.key_names, cf.value_names
        )
        part.hints.pop(rid, None)
        if part.commitlog is not None:
            part.flushed_lsn[rid] = part.commitlog.next_lsn
        self._invalidate_result_cache(cf.name, replica_id=rid)

    def _repair_replica(
        self, cf: ColumnFamily, part: Partition, r: ReplicaHandle
    ) -> None:
        """Heal one *live* replica in place from the partition log — the
        read-repair / scrub action. Only this replica's table, memtable
        and cached results are replaced; the caller bumps the counter
        that names the trigger (``read_repairs`` / ``scrub_repairs``)."""
        self._install_rebuilt(
            cf, part, r, self._rebuild_replica_table(cf, part, r)
        )

    def node_up(self, node_id: int) -> float:
        """Bring a transiently failed node back, healing each hosted
        partition replica by **hinted handoff**: replay only the log
        tail past the hint watermark and merge it into the surviving
        table — one sorted run of exactly the missed rows. A partition
        that committed nothing during the outage costs nothing (the
        common case that makes short outages cheap). Falls back to the
        full ``recover_node`` rebuild — counted in ``hint_fallbacks`` —
        when the table is gone (durable failure), the watermark predates
        a checkpoint collapse (``CommitLog.can_replay_from``), or no
        hint was recorded. Returns wall seconds; bringing up a live node
        is a no-op returning 0.0."""
        if not 0 <= node_id < len(self.nodes):
            raise ValueError(
                f"unknown node {node_id} (cluster has {len(self.nodes)})"
            )
        node = self.nodes[node_id]
        if node.alive:
            return 0.0
        t0 = time.perf_counter()
        node.alive = True
        for cf_name in self.column_families:
            self._invalidate_result_cache(cf_name, node_id=node_id)
        for cf in self.column_families.values():
            for part in cf.partitions:
                for r in part.replicas:
                    if r.node_id != node_id:
                        continue
                    rid = r.replica_id
                    log = part.commitlog
                    table = node.tables.get((cf.name, rid))
                    hint = part.hints.pop(rid, None)
                    if (
                        table is None
                        or hint is None
                        or log is None
                        or not log.can_replay_from(hint)
                    ):
                        self._hint_fallbacks.inc()
                        self._install_rebuilt(
                            cf, part, r, self._rebuild_replica_table(cf, part, r)
                        )
                        continue
                    kc, vc = log.replay_columns(start_lsn=hint)
                    n_rows = next(iter(kc.values())).shape[0] if kc else 0
                    if n_rows:
                        run = sort_run(kc, vc, r.layout, cf.schema)
                        merged = table.merge_run(run)
                        if cf.device_resident and not merged.device_resident:
                            merged.place_on_device()
                        self._ensure_views(cf, merged)
                        if self.checksums:
                            if table.stored_digest is not None:
                                merged.stored_digest = combine_digests(
                                    table.stored_digest, run.digest
                                )
                            else:
                                merged.seal_checksum()
                        node.tables[(cf.name, rid)] = merged
                        self._hint_replays.inc()
                        self._hint_rows_replayed.inc(n_rows)
                    # zero missed rows: the surviving table is already
                    # complete — no merge, no re-seal, no device work
                    part.flushed_lsn[rid] = log.next_lsn
                    part.memtables[rid] = Memtable(
                        r.layout, cf.schema, cf.key_names, cf.value_names
                    )
        return time.perf_counter() - t0

    def recover_node(self, node_id: int, *, source: str = "log") -> float:
        """Rebuild every replica the node hosted, in that replica's own
        heterogeneous layout. Returns wall seconds (§5.4 bench);
        recovering a node that is already live is a no-op returning 0.0
        (its tables are intact — use ``node_up`` for hinted heal after
        a transient failure, or ``scrub_column_family`` to audit).

        Recovery is partition-aware: only the partition replicas the
        node actually hosted are rebuilt, each from *its own
        partition's* state — the other partitions (and their logs) are
        never touched.

        ``source="log"`` (default) replays the owning partition's
        commit log: the layout-agnostic record stream — that
        partition's base rows plus every committed write it owns,
        including ones the dead node missed and rows that were
        staged-but-unflushed anywhere when the node died — is sorted
        into the lost replica's layout. The result is the same dataset
        and serialization the surviving-peer path produces
        (bit-identical packed keys and key columns; value columns too
        whenever composite keys are unique — the tie order among
        duplicate full keys is the only degree of freedom).

        ``source="survivor"`` keeps the original path: stream a
        surviving replica of the same partition and re-sort it (same
        row slice, different serialization). It is also the fallback
        for partitions without a commit log.
        """
        if source not in ("log", "survivor"):
            raise ValueError(f"unknown recovery source {source!r}")
        if not 0 <= node_id < len(self.nodes):
            raise ValueError(
                f"unknown node {node_id} (cluster has {len(self.nodes)})"
            )
        node = self.nodes[node_id]
        if node.alive:
            return 0.0
        t0 = time.perf_counter()
        node.alive = True
        for cf_name in self.column_families:
            self._invalidate_result_cache(cf_name, node_id=node_id)
        for cf in self.column_families.values():
            for part in cf.partitions:
                for r in part.replicas:
                    if r.node_id != node_id:
                        continue
                    self._install_rebuilt(
                        cf,
                        part,
                        r,
                        self._rebuild_replica_table(cf, part, r, source=source),
                    )
        return time.perf_counter() - t0

    def scrub_column_family(self, cf_name: str, *, repair: bool = True) -> dict:
        """Audit every live replica's content checksum (sealed at
        install time) against its arrays and heal mismatches from the
        partition log. The anti-entropy sweep of the availability layer:
        silent corruption that digest reads have not yet tripped over is
        found and repaired here. Returns
        ``{"replicas_checked", "corrupt", "repaired"}``; with
        ``repair=False`` corruption is only reported. Replicas without a
        sealed checksum (``checksums=False`` engines) verify trivially.

        On a views CF the sweep also audits the *derived* per-block
        partials against a fresh recompute from the (just-verified)
        resident arrays: a corrupted or missing view is healed by
        rebuild — no log replay, the base arrays are the ground truth —
        and counted under both ``scrub_repairs`` and ``view_rebuilds``.
        """
        cf = self.column_families[cf_name]
        checked = 0
        corrupt: list[int] = []
        repaired = 0
        for part in cf.partitions:
            for r in part.replicas:
                node = self.nodes[r.node_id]
                if not node.alive:
                    continue
                table = node.tables.get((cf.name, r.replica_id))
                if table is None:
                    continue
                checked += 1
                self._scrub_checks.inc()
                if table.verify_checksum():
                    if cf.views and (
                        not table.has_views or not verify_views(table)
                    ):
                        # base arrays verified, derived partials did
                        # not: heal from the arrays themselves — one
                        # kernel pass, no log replay
                        corrupt.append(r.replica_id)
                        if repair:
                            table.build_views()
                            self._view_rebuilds.inc()
                            self._scrub_repairs.inc()
                            self._invalidate_result_cache(
                                cf.name, replica_id=r.replica_id
                            )
                            repaired += 1
                    continue
                corrupt.append(r.replica_id)
                if repair:
                    self._repair_replica(cf, part, r)
                    self._scrub_repairs.inc()
                    repaired += 1
        return {
            "replicas_checked": checked,
            "corrupt": corrupt,
            "repaired": repaired,
        }

    # -- introspection -------------------------------------------------------------

    def layouts(self, cf_name: str) -> tuple[tuple[str, ...], ...]:
        """Per-replica layouts, flat in global replica-id order (every
        partition serializes slot ``s`` as ``slot_layouts[s]``, so a
        P-partition CF repeats the RF slot layouts P times)."""
        return tuple(r.layout for r in self.column_families[cf_name].replicas)

    def total_bytes(self) -> int:
        return sum(n.bytes_stored() for n in self.nodes)
