"""Paper-faithful heterogeneous-replica core (Qiao et al., 2018).

Layers: composite keys → SortedTable (SSTable analogue) → ECDF stats →
cost model (Eq 1–4) → HRCA (Alg 1) → HREngine (paper §4).
"""

from .cost_model import (
    CostModel,
    LinearCostFunction,
    estimate_rows,
    estimate_rows_many,
    precompute_query_stats,
)
from .ecdf import ColumnStats, TableStats
from .engine import (
    ALL,
    CONSISTENCY_LEVELS,
    ONE,
    QUORUM,
    ColumnFamily,
    CorruptRunError,
    DeadlineExceeded,
    HREngine,
    Node,
    ReadReport,
    ReplicaHandle,
    TransientFault,
    TransientFlushError,
    TransientReadError,
)
from .hrca import HRCAResult, exhaustive_search, hrca, initial_state
from .keys import KeySchema, pack_columns, pack_tuple, unpack_key
from .ring import Partition, TokenHistogram, TokenRing, place_replica
from .storage import (
    CommitLog,
    CompactionPolicy,
    LogRecord,
    Memtable,
    SortedRun,
    combine_digests,
    content_digest,
    run_crc32,
)
from .table import (
    ScanResult,
    SortedTable,
    merge_partial_scans,
    slab_bounds_for,
    slab_bounds_many,
)
from .workload import Eq, Query, Range, Workload, random_workload

__all__ = [
    "CostModel",
    "LinearCostFunction",
    "estimate_rows",
    "estimate_rows_many",
    "precompute_query_stats",
    "ColumnStats",
    "TableStats",
    "ColumnFamily",
    "HREngine",
    "Node",
    "ReadReport",
    "ReplicaHandle",
    "ONE",
    "QUORUM",
    "ALL",
    "CONSISTENCY_LEVELS",
    "TransientFault",
    "TransientReadError",
    "TransientFlushError",
    "CorruptRunError",
    "DeadlineExceeded",
    "Partition",
    "TokenHistogram",
    "TokenRing",
    "place_replica",
    "HRCAResult",
    "exhaustive_search",
    "hrca",
    "initial_state",
    "KeySchema",
    "pack_columns",
    "pack_tuple",
    "unpack_key",
    "CommitLog",
    "CompactionPolicy",
    "LogRecord",
    "Memtable",
    "SortedRun",
    "combine_digests",
    "content_digest",
    "run_crc32",
    "ScanResult",
    "SortedTable",
    "merge_partial_scans",
    "slab_bounds_for",
    "slab_bounds_many",
    "Eq",
    "Query",
    "Range",
    "Workload",
    "random_workload",
]
