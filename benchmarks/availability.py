"""Availability layer: hinted-handoff heal speed and the QUORUM tax.

Two measurements:

* **Hint replay vs full log replay.** A 3-node / RF=3 / 8-partition
  cluster loses one node transiently; the outage writes are keyed so
  they all land in one partition's token range. ``node_up`` replays
  only the hinted log tails — one small merge, seven skipped
  partitions — while ``recover_node(source="log")`` re-sorts every
  hosted replica from the full log. The wall-clock ratio is the point
  of hinted handoff: heal cost proportional to what was *missed*, not
  to what is *stored*.

* **QUORUM vs ONE read throughput.** The same batch of mixed queries
  at both consistency levels (result cache off, so every read touches
  replicas). QUORUM pays k−1 extra digest scans per query; the ratio
  is the price of entropy detection on the read path.

``hint_heal_rows_per_sec`` / ``full_heal_rows_per_sec`` and
``one_qps`` / ``quorum_qps`` feed the CI regression gate
(``scripts/bench_gate.py``); ``hint_speedup`` and ``quorum_over_one``
ride along as descriptive ratios.
"""

from __future__ import annotations

import copy
import time

import numpy as np

from repro.core import HREngine, ONE, QUORUM, random_workload
from repro.core.tpch import generate_simulation
from .common import record, time_fn

LAYOUTS = [("k0", "k1", "k2"), ("k1", "k2", "k0"), ("k2", "k0", "k1")]


def _build(kc, vc, schema, *, partitions, result_cache=True):
    eng = HREngine(n_nodes=3, result_cache=result_cache)
    eng.create_column_family(
        "cf", kc, vc, replication_factor=3, layouts=LAYOUTS,
        schema=schema, partitions=partitions,
    )
    return eng


def run(
    n_rows: int = 120_000,
    outage_rows: int = 2_000,
    partitions: int = 8,
    n_queries: int = 16,
    repeats: int = 5,
    seed: int = 0,
) -> dict:
    kc, vc, schema = generate_simulation(n_rows, 3, seed=seed)
    rng = np.random.default_rng(seed + 1)

    # -- heal paths: transient outage, writes pinned to one partition --
    eng = _build(kc, vc, schema, partitions=partitions)
    victim = 0  # RF = n_nodes: every node hosts a replica of every partition
    eng.fail_node(victim, transient=True)
    # constant key -> one token -> every missed write hints exactly one
    # of the victim's eight partitions
    const = {c: np.zeros(outage_rows, dtype=np.int64) for c in ("k0", "k1", "k2")}
    eng.write("cf", const, {"metric": rng.uniform(0, 1, outage_rows)})

    def best_heal(heal):
        t = float("inf")
        for _ in range(repeats):
            e = copy.deepcopy(eng)  # identical outage state per trial
            t0 = time.perf_counter()
            heal(e)
            t = min(t, time.perf_counter() - t0)
        return t

    t_hint = best_heal(lambda e: e.node_up(victim))
    t_full = best_heal(lambda e: e.recover_node(victim, source="log"))
    speedup = t_full / max(t_hint, 1e-12)
    record("availability/hint_replay", t_hint * 1e6, f"{outage_rows} missed rows")
    record("availability/full_log_replay", t_full * 1e6, f"speedup={speedup:.1f}x")

    # -- read-consistency tax ------------------------------------------------
    reng = _build(kc, vc, schema, partitions=1, result_cache=False)
    wl = random_workload(rng, schema, list(kc), n_queries)
    qs = list(wl.queries)

    def batch(level):
        return reng.read_many("cf", qs, consistency=level)

    t_one, _ = time_fn(batch, ONE, repeats=repeats, best=True)
    t_quorum, _ = time_fn(batch, QUORUM, repeats=repeats, best=True)
    one_qps = n_queries / max(t_one, 1e-12)
    quorum_qps = n_queries / max(t_quorum, 1e-12)
    tax = t_quorum / max(t_one, 1e-12)
    record("availability/read_one", t_one * 1e6, f"{one_qps:,.0f} q/s")
    record("availability/read_quorum", t_quorum * 1e6, f"tax={tax:.2f}x")

    return {
        "hint_s": t_hint,
        "full_s": t_full,
        "hint_speedup": speedup,
        "hint_heal_rows_per_sec": outage_rows / max(t_hint, 1e-12),
        "full_heal_rows_per_sec": n_rows / max(t_full, 1e-12),
        "one_qps": one_qps,
        "quorum_qps": quorum_qps,
        "quorum_over_one": tax,
    }


if __name__ == "__main__":
    print(run())
