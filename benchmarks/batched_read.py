"""Batched read throughput — ``read_many`` vs a sequential ``read`` loop,
plus the device-kernel perf trajectory (``--device``).

The paper's speedup is per-query (route to the replica minimizing
Row(r, q)); at production traffic queries arrive in batches, and the
batched path amortizes replica ranking (vectorized Eq 1–2), slab
location (one searchsorted over packed bounds) and scan dispatch across
the batch. Reported: queries/sec for

  * ``hr_seq``    — sequential HR ``read`` loop (the old path)
  * ``hr_batch``  — ``read_many`` on the same HR column family
  * ``tr_seq`` / ``tr_batch`` — the expert-TR baseline, both paths

on the TPC-H-style Q1/Q2 workload, per batch size. Per-query results
are asserted identical between the two HR paths (same values, same
rows_scanned) — the batch is a scheduling optimization, not an
approximation.

``--device`` additionally benchmarks one replica's storage scan across
the four batched engines and records queries/sec per batch size in
``BENCH_batched_read.json`` (machine-readable perf trajectory):

  * ``numpy``   — ``SortedTable.execute_many`` residual scan (reference)
  * ``qgrid``   — PR 1 Pallas grid (queries outer, row blocks inner:
                  key tiles re-fetched per query)
  * ``rowgrid`` — PR 2 row-streaming grid (row blocks outer, per-query
                  accumulators revisited: columns stream once per batch)
                  over HOST-searchsorted slabs — the pre-fusion baseline
  * ``fused``   — PR 3 fused locate+scan (slab location inside the scan
                  predicate: zero host searchsorted, one launch, int32
                  counts)

The engines are constructed with ``result_cache=False`` so repeated
timing iterations measure the scan path, not the engine's read result
cache.
"""

from __future__ import annotations

import itertools
import json

import numpy as np

from repro.core import HREngine, Query, Range, SortedTable
from repro.core.tpch import generate_orders, n_custkey, orders_schema, q1_q2_workload
from repro.kernels import table_execute_device_many, table_scan_device_many

from .common import record, time_fn


def run_device(
    n_rows: int = 120_000,
    batch_sizes=(16, 64, 256),
    seed: int = 0,
    repeats: int = 3,
    best: bool = False,
) -> dict:
    """numpy vs queries-outer vs row-streaming vs fused, one replica.

    All four answer the identical sum-aggregation batch (the legacy
    qgrid cannot mix aggregation kinds); results are cross-checked
    before timing — fused counts/rows_scanned must equal the numpy
    reference bit-for-bit, sums to float32 accumulation. The qgrid and
    rowgrid lambdas locate slabs with the HOST searchsorted (the
    pre-fusion read path, timed end to end); fused locates on device
    inside the scan launch. Returns {batch_size: {engine: q/s, ...}}.
    """
    kc, vc = generate_orders(1.0, seed=seed, rows_per_sf=n_rows)
    wl = q1_q2_workload(max(batch_sizes), seed=seed + 1, n_rows=n_rows)
    queries_all = [
        Query(filters=q.filters, agg="sum", value_col="totalprice")
        for q in wl.queries
    ]
    dev = SortedTable.from_columns(
        kc, vc, ("custkey", "orderdate", "clerk"), orders_schema()
    ).place_on_device()
    # host-path twin sharing the same column arrays (no device cache)
    host = SortedTable(dev.layout, dev.schema, dev.key_cols, dev.value_cols, dev.packed)

    out: dict = {}
    for bs in batch_sizes:
        queries = queries_all[:bs]
        # warm up every kernel variant (jit compile outside the timing)
        row = table_scan_device_many(
            dev, queries, slabs=host.slab_many(queries), grid="rows_outer"
        )
        qgr = table_scan_device_many(
            dev, queries, slabs=host.slab_many(queries), grid="queries_outer"
        )
        fus = table_execute_device_many(dev, queries)
        ref = host.execute_many(queries)
        for r, (s_row, c_row), (s_q, c_q), rf in zip(ref, row, qgr, fus):
            assert c_row == c_q == r.rows_matched, "device scan diverged"
            assert rf.rows_matched == r.rows_matched, "fused counts diverged"
            assert rf.rows_scanned == r.rows_scanned, "fused slab rows diverged"
            np.testing.assert_allclose(s_row, r.value, rtol=1e-5)
            np.testing.assert_allclose(s_q, r.value, rtol=1e-5)
            np.testing.assert_allclose(rf.value, r.value, rtol=1e-5)

        t_np, _ = time_fn(lambda: host.execute_many(queries), repeats=repeats, best=best)
        t_qg, _ = time_fn(
            lambda: table_scan_device_many(
                dev, queries, slabs=host.slab_many(queries), grid="queries_outer"
            ),
            repeats=repeats,
            best=best,
        )
        t_rg, _ = time_fn(
            lambda: table_scan_device_many(
                dev, queries, slabs=host.slab_many(queries), grid="rows_outer"
            ),
            repeats=repeats,
            best=best,
        )
        t_fu, _ = time_fn(
            lambda: table_execute_device_many(dev, queries), repeats=repeats, best=best
        )
        res = {
            "numpy_qps": bs / max(t_np, 1e-12),
            "qgrid_qps": bs / max(t_qg, 1e-12),
            "rowgrid_qps": bs / max(t_rg, 1e-12),
            "fused_qps": bs / max(t_fu, 1e-12),
        }
        res["rowgrid_over_qgrid"] = res["rowgrid_qps"] / res["qgrid_qps"]
        res["rowgrid_over_numpy"] = res["rowgrid_qps"] / res["numpy_qps"]
        res["fused_over_rowgrid"] = res["fused_qps"] / res["rowgrid_qps"]
        out[bs] = res
        record(f"batched/device_bs{bs}_numpy", t_np / bs * 1e6, f"qps={res['numpy_qps']:.0f}")
        record(f"batched/device_bs{bs}_qgrid", t_qg / bs * 1e6, f"qps={res['qgrid_qps']:.0f}")
        record(
            f"batched/device_bs{bs}_rowgrid", t_rg / bs * 1e6,
            f"qps={res['rowgrid_qps']:.0f};vs_qgrid={res['rowgrid_over_qgrid']:.2f}x",
        )
        record(
            f"batched/device_bs{bs}_fused", t_fu / bs * 1e6,
            f"qps={res['fused_qps']:.0f};vs_rowgrid={res['fused_over_rowgrid']:.2f}x",
        )
    return out


def run_views(
    n_rows: int = 120_000,
    batch_sizes=(16, 64, 256),
    seed: int = 0,
    repeats: int = 3,
    best: bool = False,
) -> dict:
    """Materialized per-slab views vs the fused full scan, one replica.

    The batch is all *view-eligible wide-slab* aggregates — a range on
    the leading layout column covering a large key span, so the fused
    engine streams most of the table while the view path reads stored
    block partials plus at most two boundary rescans per (query, run).
    This is the workload the views tentpole targets: O(blocks touched)
    vs O(N). Answers are cross-checked BITWISE against the fused launch
    before timing (the views correctness bar — same float32 partials,
    same sequential block-order fold). Returns
    ``{batch_size: {views_qps, fused_qps, views_over_fused_speedup}}``.
    """
    kc, vc = generate_orders(1.0, seed=seed, rows_per_sf=n_rows)
    rng = np.random.default_rng(seed + 7)
    nck = n_custkey(n_rows)
    queries_all = []
    for i in range(max(batch_sizes)):
        lo = int(rng.integers(0, nck // 4))
        hi = int(rng.integers(nck // 2, nck + 1))
        queries_all.append(
            Query(
                filters={"custkey": Range(lo, hi)},
                agg="sum" if i % 2 == 0 else "count",
                value_col="totalprice",
            )
        )
    tv = SortedTable.from_columns(
        kc, vc, ("custkey", "orderdate", "clerk"), orders_schema()
    ).place_on_device()
    tv.build_views()
    tf = SortedTable.from_columns(
        kc, vc, ("custkey", "orderdate", "clerk"), orders_schema()
    ).place_on_device()

    out: dict = {}
    for bs in batch_sizes:
        queries = queries_all[:bs]
        # warm up both paths (jit compile outside the timing) and hold
        # the bit-identity bar: view answers == fused answers, exactly
        stats: dict = {}
        rv = tv.execute_many(queries, view_stats=stats)
        rf = table_execute_device_many(tf, queries)
        assert stats.get("hits") == bs, "a views bench query missed the view path"
        for q, a, b in zip(queries, rv, rf):
            assert a.value == b.value, f"view answer diverged from fused: {q}"
            assert a.rows_matched == b.rows_matched
            assert a.rows_scanned == b.rows_scanned

        t_vw, _ = time_fn(
            lambda: tv.execute_many(queries), repeats=repeats, best=best
        )
        t_fu, _ = time_fn(
            lambda: table_execute_device_many(tf, queries),
            repeats=repeats, best=best,
        )
        res = {
            "views_qps": bs / max(t_vw, 1e-12),
            "fused_qps": bs / max(t_fu, 1e-12),
        }
        res["views_over_fused_speedup"] = res["views_qps"] / res["fused_qps"]
        out[bs] = res
        record(
            f"views/bs{bs}_fused", t_fu / bs * 1e6,
            f"qps={res['fused_qps']:.0f}",
        )
        record(
            f"views/bs{bs}_views", t_vw / bs * 1e6,
            f"qps={res['views_qps']:.0f};"
            f"vs_fused={res['views_over_fused_speedup']:.2f}x",
        )
    return out


def run(
    n_rows: int = 120_000,
    batch_sizes=(16, 64, 256),
    seed: int = 0,
    device: bool = False,
    json_path: str | None = None,
    repeats: int = 3,
    best: bool = False,
) -> dict:
    """``repeats`` feeds ``time_fn`` (median-of-N); the smoke/CI gate
    uses a higher count *and* best-of-N (``best=True``) because its
    toy-scale per-call times are small enough for scheduler jitter to
    swing the median queries/sec by 2x run to run."""
    sf = 1.0
    kc, vc = generate_orders(sf, seed=seed, rows_per_sf=n_rows)
    wl = q1_q2_workload(max(batch_sizes), seed=seed + 1, n_rows=n_rows)
    # no result cache: the timing loop repeats the same batch, and this
    # benchmark measures the scheduler+scan path, not cache hits
    eng = HREngine(n_nodes=6, result_cache=False)
    eng.create_column_family(
        "hr", kc, vc, replication_factor=3, mechanism="HR", workload=wl,
        schema=orders_schema(), hrca_kwargs={"k_max": 2500, "seed": 0},
    )
    eng.create_column_family(
        "tr", kc, vc, replication_factor=3, mechanism="TR", workload=wl,
        schema=orders_schema(),
    )

    out: dict = {"n_rows": n_rows}
    for bs in batch_sizes:
        queries = wl.queries[:bs]
        res: dict = {}
        for mech in ("hr", "tr"):
            # reads mutate nothing but the RR tie-break counter — reset it
            # so both paths schedule from the identical state
            cf = eng.column_families[mech]
            cf.rr_counter = itertools.count()
            t_seq, seq = time_fn(
                lambda: [eng.read(mech, q) for q in queries], repeats=repeats, best=best
            )
            cf.rr_counter = itertools.count()
            t_bat, bat = time_fn(
                lambda: eng.read_many(mech, queries), repeats=repeats, best=best
            )
            for (rs, rep_s), (rb, rep_b) in zip(seq, bat):
                assert rb.value == rs.value, "batched result diverged"
                assert rb.rows_scanned == rep_s.rows_scanned == rep_b.rows_scanned
            qps_seq = bs / max(t_seq, 1e-12)
            qps_bat = bs / max(t_bat, 1e-12)
            res[mech] = (qps_seq, qps_bat)
            record(
                f"batched/bs{bs}_{mech}_seq", t_seq / bs * 1e6,
                f"qps={qps_seq:.0f}",
            )
            record(
                f"batched/bs{bs}_{mech}_batch", t_bat / bs * 1e6,
                f"qps={qps_bat:.0f};speedup={qps_bat / qps_seq:.2f}x",
            )
        out[bs] = {
            "hr_seq_qps": res["hr"][0],
            "hr_batch_qps": res["hr"][1],
            "hr_speedup": res["hr"][1] / res["hr"][0],
            "tr_seq_qps": res["tr"][0],
            "tr_batch_qps": res["tr"][1],
            "tr_speedup": res["tr"][1] / res["tr"][0],
        }

    if device:
        out["device"] = run_device(
            n_rows=n_rows, batch_sizes=batch_sizes, seed=seed, repeats=repeats,
            best=best,
        )
    if json_path:
        # merge into the existing document: this file also carries the
        # CI gate's smoke_baseline section, which a results refresh must
        # not silently delete
        doc = {}
        try:
            with open(json_path) as f:
                doc = json.load(f)
        except (FileNotFoundError, json.JSONDecodeError):
            pass
        doc.update(out)
        with open(json_path, "w") as f:
            json.dump(doc, f, indent=1, default=str)
    return out


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--rows", type=int, default=120_000)
    ap.add_argument(
        "--device", action="store_true",
        help="also benchmark numpy vs queries-outer vs row-streaming device scans",
    )
    ap.add_argument(
        "--json", default="BENCH_batched_read.json",
        help="where to record queries/sec (written when --device is set)",
    )
    args = ap.parse_args()
    for k, v in run(
        n_rows=args.rows, device=args.device,
        json_path=args.json if args.device else None,
    ).items():
        print(k, v)
