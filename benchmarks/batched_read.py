"""Batched read throughput — ``read_many`` vs a sequential ``read`` loop.

The paper's speedup is per-query (route to the replica minimizing
Row(r, q)); at production traffic queries arrive in batches, and the
batched path amortizes replica ranking (vectorized Eq 1–2), slab
location (one searchsorted over packed bounds) and scan dispatch across
the batch. Reported: queries/sec for

  * ``hr_seq``    — sequential HR ``read`` loop (the old path)
  * ``hr_batch``  — ``read_many`` on the same HR column family
  * ``tr_seq`` / ``tr_batch`` — the expert-TR baseline, both paths

on the TPC-H-style Q1/Q2 workload, per batch size. Per-query results
are asserted identical between the two HR paths (same values, same
rows_scanned) — the batch is a scheduling optimization, not an
approximation.
"""

from __future__ import annotations

import itertools

from repro.core import HREngine
from repro.core.tpch import generate_orders, orders_schema, q1_q2_workload
from .common import record, time_fn


def run(
    n_rows: int = 120_000,
    batch_sizes=(16, 64, 256),
    seed: int = 0,
) -> dict:
    sf = 1.0
    kc, vc = generate_orders(sf, seed=seed, rows_per_sf=n_rows)
    wl = q1_q2_workload(max(batch_sizes), seed=seed + 1, n_rows=n_rows)
    eng = HREngine(n_nodes=6)
    eng.create_column_family(
        "hr", kc, vc, replication_factor=3, mechanism="HR", workload=wl,
        schema=orders_schema(), hrca_kwargs={"k_max": 2500, "seed": 0},
    )
    eng.create_column_family(
        "tr", kc, vc, replication_factor=3, mechanism="TR", workload=wl,
        schema=orders_schema(),
    )

    out: dict = {"n_rows": n_rows}
    for bs in batch_sizes:
        queries = wl.queries[:bs]
        res: dict = {}
        for mech in ("hr", "tr"):
            # reads mutate nothing but the RR tie-break counter — reset it
            # so both paths schedule from the identical state
            cf = eng.column_families[mech]
            cf.rr_counter = itertools.count()
            t_seq, seq = time_fn(lambda: [eng.read(mech, q) for q in queries])
            cf.rr_counter = itertools.count()
            t_bat, bat = time_fn(lambda: eng.read_many(mech, queries))
            for (rs, rep_s), (rb, rep_b) in zip(seq, bat):
                assert rb.value == rs.value, "batched result diverged"
                assert rb.rows_scanned == rep_s.rows_scanned == rep_b.rows_scanned
            qps_seq = bs / max(t_seq, 1e-12)
            qps_bat = bs / max(t_bat, 1e-12)
            res[mech] = (qps_seq, qps_bat)
            record(
                f"batched/bs{bs}_{mech}_seq", t_seq / bs * 1e6,
                f"qps={qps_seq:.0f}",
            )
            record(
                f"batched/bs{bs}_{mech}_batch", t_bat / bs * 1e6,
                f"qps={qps_bat:.0f};speedup={qps_bat / qps_seq:.2f}x",
            )
        out[bs] = {
            "hr_seq_qps": res["hr"][0],
            "hr_batch_qps": res["hr"][1],
            "hr_speedup": res["hr"][1] / res["hr"][0],
            "tr_seq_qps": res["tr"][0],
            "tr_batch_qps": res["tr"][1],
            "tr_speedup": res["tr"][1] / res["tr"][0],
        }
    return out


if __name__ == "__main__":
    for k, v in run().items():
        print(k, v)
