"""Fig 5a/5d — TR vs HR average query latency vs TPC-H data size.

Paper claim (C1): on TPC-H ``orders`` with Q1/Q2 instances, HR cuts the
average query latency 1–2 orders of magnitude, and the TR cost grows
with data size while HR stays ~flat.

Two TR baselines are reported:
  * ``tr_defined`` — the schema's declared clustering order
    (custkey, orderdate, clerk). This is the baseline whose Q1 cost is
    O(table) and reproduces the paper's 1–2 orders of magnitude.
  * ``tr_expert`` — the best SINGLE layout by exhaustive search (a
    *stronger* baseline than the paper's: here clerk-first serves both
    query classes). HR's residual gain over it is the honest margin of
    heterogeneity once the homogeneous layout is chosen optimally.

Scale factors are scaled down for CPU wall-clock (rows_per_sf
configurable); the *relative* gain is the reproduced quantity — both
mechanisms stream the same bytes per row on any hardware.
"""

from __future__ import annotations

import numpy as np

from repro.core import HREngine
from repro.core.tpch import generate_orders, orders_schema, q1_q2_workload
from .common import record, time_fn


def run(
    scale_factors=(1, 2, 3, 4, 5),
    rows_per_sf: int = 60_000,
    n_queries: int = 100,
    seed: int = 0,
) -> dict:
    out = {}
    for sf in scale_factors:
        n_rows = int(sf * rows_per_sf)
        wl = q1_q2_workload(n_queries, seed=seed + 1, n_rows=n_rows)
        kc, vc = generate_orders(sf, seed=seed, rows_per_sf=rows_per_sf)
        # no result cache: duplicate workload queries must pay the scan,
        # or the paper's latency figures deflate
        eng = HREngine(n_nodes=6, result_cache=False)
        defined = ("custkey", "orderdate", "clerk")
        eng.create_column_family(
            "tr_defined", kc, vc, replication_factor=3, workload=wl,
            schema=orders_schema(), layouts=[defined] * 3,
        )
        eng.create_column_family(
            "tr_expert", kc, vc, replication_factor=3, mechanism="TR", workload=wl,
            schema=orders_schema(),
        )
        eng.create_column_family(
            "hr", kc, vc, replication_factor=3, mechanism="HR", workload=wl,
            schema=orders_schema(), hrca_kwargs={"k_max": 2500, "seed": 0},
        )

        stats = {}
        for mech in ("tr_defined", "tr_expert", "hr"):
            wall = rows = 0.0
            for q in wl.queries:
                res, rep = eng.read(mech, q)
                wall += rep.wall_seconds
                rows += rep.rows_scanned
            stats[mech] = (wall / len(wl), rows / len(wl))
        hr_rows = max(stats["hr"][1], 1e-9)
        gain_rows = stats["tr_defined"][1] / hr_rows
        gain_expert = stats["tr_expert"][1] / hr_rows
        gain_wall = stats["tr_defined"][0] / max(stats["hr"][0], 1e-12)
        record(f"fig5a/sf{sf}_tr_defined", stats["tr_defined"][0] * 1e6,
               f"rows={stats['tr_defined'][1]:.1f}")
        record(f"fig5a/sf{sf}_tr_expert", stats["tr_expert"][0] * 1e6,
               f"rows={stats['tr_expert'][1]:.1f}")
        record(
            f"fig5a/sf{sf}_hr", stats["hr"][0] * 1e6,
            f"rows={stats['hr'][1]:.1f};gain_vs_defined={gain_rows:.0f}x;"
            f"gain_vs_expert={gain_expert:.1f}x",
        )
        out[sf] = {
            "tr_defined_us": stats["tr_defined"][0] * 1e6,
            "tr_expert_us": stats["tr_expert"][0] * 1e6,
            "hr_us": stats["hr"][0] * 1e6,
            "tr_defined_rows": stats["tr_defined"][1],
            "tr_expert_rows": stats["tr_expert"][1],
            "hr_rows": stats["hr"][1],
            "gain_rows": gain_rows,
            "gain_vs_expert": gain_expert,
            "gain_wall": gain_wall,
            "hr_layouts": [list(a) for a in eng.layouts("hr")],
            "tr_expert_layout": list(eng.layouts("tr_expert")[0]),
        }
    return out


if __name__ == "__main__":
    for sf, r in run().items():
        print(sf, r)
