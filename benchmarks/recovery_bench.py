"""§5.4 — recovery speed: homogeneous copy vs heterogeneous rebuild.

Paper claim (C5): recovering a heterogeneous replica takes ~1.5× a plain
copy (4 min → 6 min in the paper) because the recovered rows must be
re-sorted into the lost replica's layout. Two heterogeneous paths are
measured against the byte-copy baseline:

* ``survivor`` — stream a surviving replica and re-sort it (the
  original §5.4 mechanism, ``recover_node(source="survivor")``).
* ``log replay`` — replay the column family's shared commit log into
  the lost layout (``recover_node(source="log")``, the durable-write-
  path default). Same dataset, bit-identical serialization; the log is
  layout-agnostic so this path also repairs writes the dead node
  missed.

The ``*_rows_per_sec`` keys feed the CI regression gate
(``scripts/bench_gate.py``) alongside the batched-read queries/sec.
"""

from __future__ import annotations

import numpy as np

from repro.core import HREngine, SortedTable, random_workload
from repro.core.tpch import generate_simulation
from .common import record, time_fn


def run(n_rows: int = 500_000, seed: int = 0) -> dict:
    kc, vc, schema = generate_simulation(n_rows, 3, seed=seed)
    rng = np.random.default_rng(seed + 1)
    wl = random_workload(rng, schema, list(kc), 20)
    eng = HREngine(n_nodes=4)
    cf = eng.create_column_family("hr", kc, vc, replication_factor=3, mechanism="HR",
                                  workload=wl, schema=schema,
                                  hrca_kwargs={"k_max": 1000, "seed": 0})

    # homogeneous recovery = byte copy of an identical replica
    src = eng._table(cf, cf.replicas[1])

    def copy_recover():
        return SortedTable(
            layout=src.layout, schema=src.schema,
            key_cols={k: v.copy() for k, v in src.key_cols.items()},
            value_cols={k: np.asarray(v).copy() for k, v in src.value_cols.items()},
            packed=src.packed.copy(),
        )

    # best-of-N: the smoke-scale runs are sub-millisecond and feed the
    # CI regression gate, where the minimum is far less jitter-prone
    # than the median (same rationale as the batched-read gate)
    t_copy, _ = time_fn(copy_recover, repeats=5, best=True)

    victim = cf.replicas[0].node_id

    def hr_recover(source):
        eng.fail_node(victim)
        return eng.recover_node(victim, source=source)

    # heterogeneous recovery (a): re-sort a surviving replica
    t_hr, _ = time_fn(hr_recover, "survivor", repeats=5, best=True)
    # heterogeneous recovery (b): replay the shared commit log
    t_replay, _ = time_fn(hr_recover, "log", repeats=5, best=True)

    ratio = t_hr / max(t_copy, 1e-12)
    replay_ratio = t_replay / max(t_copy, 1e-12)
    record("recovery/homogeneous_copy", t_copy * 1e6, "")
    record("recovery/heterogeneous_resort", t_hr * 1e6, f"ratio={ratio:.2f}x")
    record("recovery/log_replay", t_replay * 1e6, f"ratio={replay_ratio:.2f}x")
    return {
        "copy_s": t_copy,
        "hr_s": t_hr,
        "replay_s": t_replay,
        "ratio": ratio,
        "replay_ratio": replay_ratio,
        "resort_rows_per_sec": n_rows / max(t_hr, 1e-12),
        "replay_rows_per_sec": n_rows / max(t_replay, 1e-12),
    }


if __name__ == "__main__":
    print(run())
