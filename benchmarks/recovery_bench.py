"""§5.4 — recovery speed: homogeneous copy vs heterogeneous re-sort.

Paper claim (C5): recovering a heterogeneous replica takes ~1.5× a plain
copy (4 min → 6 min in the paper) because the survivor's rows must be
re-sorted into the lost replica's layout.
"""

from __future__ import annotations

import numpy as np

from repro.core import HREngine, SortedTable, random_workload
from repro.core.tpch import generate_simulation
from .common import record, time_fn


def run(n_rows: int = 500_000, seed: int = 0) -> dict:
    kc, vc, schema = generate_simulation(n_rows, 3, seed=seed)
    rng = np.random.default_rng(seed + 1)
    wl = random_workload(rng, schema, list(kc), 20)
    eng = HREngine(n_nodes=4)
    cf = eng.create_column_family("hr", kc, vc, replication_factor=3, mechanism="HR",
                                  workload=wl, schema=schema,
                                  hrca_kwargs={"k_max": 1000, "seed": 0})

    # homogeneous recovery = byte copy of an identical replica
    src = eng._table(cf, cf.replicas[1])

    def copy_recover():
        return SortedTable(
            layout=src.layout, schema=src.schema,
            key_cols={k: v.copy() for k, v in src.key_cols.items()},
            value_cols={k: np.asarray(v).copy() for k, v in src.value_cols.items()},
            packed=src.packed.copy(),
        )

    t_copy, _ = time_fn(copy_recover, repeats=3)

    # heterogeneous recovery = engine rebuild (re-sort survivor)
    victim = cf.replicas[0].node_id

    def hr_recover():
        eng.fail_node(victim)
        return eng.recover_node(victim)

    t_hr, _ = time_fn(hr_recover, repeats=3)
    ratio = t_hr / max(t_copy, 1e-12)
    record("recovery/homogeneous_copy", t_copy * 1e6, "")
    record("recovery/heterogeneous_resort", t_hr * 1e6, f"ratio={ratio:.2f}x")
    return {"copy_s": t_copy, "hr_s": t_hr, "ratio": ratio}


if __name__ == "__main__":
    print(run())
