"""Benchmark runner — one section per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (and optionally writes them
to --csv). Default sizes finish on CPU in a few minutes; --full uses
paper-scale row counts; --smoke runs every registered benchmark at toy
scale (the pre-merge gate, see scripts/ci.sh).
"""

from __future__ import annotations

import argparse
import json
import sys


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true", help="paper-scale sizes (slow)")
    ap.add_argument(
        "--smoke", action="store_true",
        help="toy-scale pass over every registered benchmark (CI gate)",
    )
    ap.add_argument("--csv", default=None)
    ap.add_argument("--json", default=None)
    ap.add_argument(
        "--only", default=None,
        help=(
            "comma list: fig4,fig5a,fig5b,fig5c,table1,recovery,hrca,"
            "kernels,batched,views,write_queue,partitioned,availability,"
            "serving"
        ),
    )
    args = ap.parse_args()
    if args.full and args.smoke:
        ap.error("--full and --smoke are mutually exclusive")
    only = set(args.only.split(",")) if args.only else None

    from . import (
        availability,
        batched_read,
        fig4_cost_model,
        fig5a_datasize,
        fig5b_repfactor,
        fig5c_clustering,
        hrca_convergence,
        kernel_bench,
        partitioned_read,
        recovery_bench,
        serving_latency,
        table1_write,
        write_queue,
    )
    from .common import ROWS, flush_csv

    full, smoke = args.full, args.smoke
    results = {}
    print("name,us_per_call,derived")

    def want(k):
        return only is None or k in only

    def size(full_size, default_size, smoke_size):
        return full_size if full else (smoke_size if smoke else default_size)

    if want("fig4"):
        results["fig4"] = fig4_cost_model.run(n_rows=size(1_000_000, 200_000, 20_000))
    if want("fig5a"):
        results["fig5a"] = fig5a_datasize.run(
            rows_per_sf=size(1_500_000, 40_000, 5_000),
            n_queries=size(500, 60, 10),
        )
    if want("fig5b"):
        results["fig5b"] = fig5b_repfactor.run(n_rows=size(10_000_000, 200_000, 20_000))
    if want("fig5c"):
        results["fig5c"] = fig5c_clustering.run(n_rows=size(10_000_000, 200_000, 20_000))
    if want("table1"):
        results["table1"] = table1_write.run(
            total_rows=size(
                (40_000_000, 80_000_000, 120_000_000),
                (40_000, 80_000, 120_000),
                (5_000, 10_000),
            )
        )
    if want("recovery"):
        # smoke numbers feed the regression gate (log-replay + resort
        # rows/sec), same as write_queue below — see scripts/bench_gate.py
        results["recovery"] = recovery_bench.run(n_rows=size(18_000_000, 300_000, 30_000))
    if want("hrca"):
        results["hrca"] = hrca_convergence.run(n_rows=size(1_000_000, 200_000, 20_000))
    if want("kernels"):
        results["kernels"] = kernel_bench.run()
    if want("batched"):
        # smoke exercises the device kernels too (tiny batches, no JSON);
        # extra timing repeats + best-of-N keep the CI regression gate's
        # toy-scale queries/sec out of scheduler-jitter territory
        results["batched"] = batched_read.run(
            n_rows=size(1_500_000, 120_000, 20_000),
            batch_sizes=(8, 16) if smoke else (16, 64, 256),
            device=smoke,
            repeats=11 if smoke else 3,
            best=smoke,
        )
    if want("views"):
        # materialized per-slab views vs the fused full scan on
        # wide-slab eligible aggregates; the smoke views_qps and the
        # views_over_fused_speedup ratio feed the regression gate (the
        # tentpole acceptance: view routing must hold its O(blocks
        # touched) advantage, bit-identical answers asserted in-bench)
        # smoke keeps the full 120k rows: the view advantage IS the
        # O(N) vs O(blocks) gap, and at toy row counts the fused scan
        # is too cheap for the gated >=5x speedup ratio to be stable
        results["views"] = batched_read.run_views(
            n_rows=size(1_500_000, 120_000, 120_000),
            batch_sizes=(8, 16) if smoke else (16, 64, 256),
            repeats=11 if smoke else 3,
            best=smoke,
        )
    if want("partitioned"):
        # q/s vs partition count at fixed dataset size; the smoke
        # p{P}_qps keys feed the regression gate (best-of-N, same
        # jitter rationale as the batched gate). The Zipf --skew
        # section also runs at smoke scale so p{P}_skew_qps (the
        # post-rebalance drain on a vnode ring) is gated too.
        results["partitioned"] = partitioned_read.run(
            n_rows=size(2_000_000, 200_000, 20_000),
            batch=size(256, 64, 16),
            n_batches=size(8, 4, 3),
            partition_counts=(1, 2, 4) if smoke else (1, 2, 4, 8),
            repeats=11 if smoke else 3,
            best=smoke,
            skew=1.3,
            skew_partitions=4 if smoke else 8,
        )
    if want("availability"):
        # hinted-handoff heal vs full log replay, and the QUORUM read
        # tax; the four throughput keys feed the regression gate while
        # hint_speedup / quorum_over_one stay descriptive
        results["availability"] = availability.run(
            n_rows=size(1_000_000, 120_000, 20_000),
            outage_rows=size(20_000, 2_000, 500),
            n_queries=size(64, 16, 8),
            repeats=11 if smoke else 5,
        )
    if want("serving"):
        # open-loop front-door latency vs offered load; the smoke
        # passthrough/direct q/s and per-load p99 keys feed the
        # regression gate (p99 gated lower-is-better, see bench_gate)
        results["serving"] = serving_latency.run(
            n_rows=size(1_000_000, 120_000, 20_000),
            batch=size(64, 64, 16),
            n_requests=size(2_000, 400, 120),
            loads=(0.25, 2.0) if smoke else (0.25, 1.0, 2.0),
            repeats=11 if smoke else 5,
            best=smoke,
        )
    if want("write_queue"):
        results["write_queue"] = write_queue.run(
            n_rows=size(1_000_000, 60_000, 8_000),
            n_batches=size(32, 16, 6),
            batch_rows=size(20_000, 2_000, 400),
        )

    import os

    if args.csv:
        os.makedirs(os.path.dirname(args.csv) or ".", exist_ok=True)
        flush_csv(args.csv)
    if args.json:
        os.makedirs(os.path.dirname(args.json) or ".", exist_ok=True)
        with open(args.json, "w") as f:
            json.dump(results, f, indent=1, default=str)
    print(f"\n[benchmarks] {len(ROWS)} rows emitted", file=sys.stderr)


if __name__ == "__main__":
    main()
