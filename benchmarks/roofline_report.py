"""Render EXPERIMENTS.md tables from dry-run / roofline artifacts.

    PYTHONPATH=src:. python -m benchmarks.roofline_report \
        --dryrun artifacts/dryrun/single_pod.json artifacts/dryrun/multi_pod.json \
        --roofline artifacts/roofline.json
"""

from __future__ import annotations

import argparse
import json


def _fmt_bytes(b):
    if b is None:
        return "-"
    for unit in ("B", "KB", "MB", "GB", "TB"):
        if abs(b) < 1024:
            return f"{b:.1f}{unit}"
        b /= 1024
    return f"{b:.1f}PB"


def dryrun_table(records) -> str:
    lines = [
        "| arch | shape | mesh | compile | args/dev | temp/dev | HLO flops/dev | coll bytes/dev |",
        "|---|---|---|---:|---:|---:|---:|---:|",
    ]
    for r in records:
        if r["status"] == "skipped":
            lines.append(
                f"| {r['arch']} | {r['shape']} | {r['mesh']} | SKIP | - | - | - | - |"
            )
            continue
        if r["status"] != "ok":
            lines.append(
                f"| {r['arch']} | {r['shape']} | {r['mesh']} | **FAIL** | - | - | - | - |"
            )
            continue
        m = r["memory"]
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | {r['compile_s']:.1f}s "
            f"| {_fmt_bytes(m['argument_bytes'])} | {_fmt_bytes(m['temp_bytes'])} "
            f"| {r['flops']:.2e} | {_fmt_bytes(r['collective_bytes_total'])} |"
        )
    return "\n".join(lines)


def roofline_table(records) -> str:
    lines = [
        "| arch | shape | Tc (ms) | Tm (ms) | Tx (ms) | dominant | useful% | roofline frac |",
        "|---|---|---:|---:|---:|---|---:|---:|",
    ]
    for r in records:
        if r.get("status") != "ok":
            if r.get("status") == "skipped":
                lines.append(f"| {r['arch']} | {r['shape']} | - | - | - | skip | - | - |")
            continue
        t = r["roofline"]
        tc, tm, tx = t["t_compute"], t["t_memory"], t["t_collective"]
        # roofline fraction: useful compute time over the bounding term
        # (how close the step is to the ideal MODEL_FLOPS-only machine)
        t_ideal = (r["model_flops_per_chip"]) / 197e12
        frac = t_ideal / max(tc, tm, tx, 1e-30)
        lines.append(
            f"| {r['arch']} | {r['shape']} | {tc*1e3:.3f} | {tm*1e3:.3f} | {tx*1e3:.3f} "
            f"| {r['dominant'][2:]} | {r['useful_flops_ratio']*100:.1f} | {frac*100:.1f}% |"
        )
    return "\n".join(lines)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dryrun", nargs="*", default=["artifacts/dryrun/single_pod.json",
                                                    "artifacts/dryrun/multi_pod.json"])
    ap.add_argument("--roofline", default="artifacts/roofline.json")
    args = ap.parse_args()

    for path in args.dryrun:
        try:
            with open(path) as f:
                recs = json.load(f)
        except FileNotFoundError:
            continue
        print(f"\n### Dry-run: {path}\n")
        print(dryrun_table(recs))
        n_ok = sum(r["status"] == "ok" for r in recs)
        n_skip = sum(r["status"] == "skipped" for r in recs)
        print(f"\n{n_ok} ok / {n_skip} documented skips / "
              f"{len(recs) - n_ok - n_skip} failed")

    try:
        with open(args.roofline) as f:
            recs = json.load(f)
    except FileNotFoundError:
        return
    print("\n### Roofline (single-pod 16×16, loop-corrected)\n")
    print(roofline_table(recs))


if __name__ == "__main__":
    main()
