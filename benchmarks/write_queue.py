"""Group-commit write queue — §5.3 writes at batch granularity.

Client writes arrive as small batches; since the durable write path
(commit log → memtable → sorted runs) landed, group commit *falls out of
memtable staging*: an engine whose staging threshold covers ``g``
batches absorbs them as cheap log appends + memtable stages and flushes
one sorted run of ``g × b`` rows per replica — one sort + one merge
instead of ``g`` (each replica still sorts its own copy, paper Table 1).
This benchmark drains the same queue of ``n_batches`` pending batches at
several group-commit sizes (``memtable_rows = g × batch_rows``) and
reports committed rows/sec.

It also measures ``HREngine.write(parallel=True)`` — the thread-pool
overlap of the independent per-replica flushes — against the sequential
default at the largest group size. The merge hot path now routes
through GIL-releasing ``np.sort`` on a concatenated packed-key buffer
plus destination scatters (``SortedTable.merge_run``) instead of
GIL-holding ``np.argsort``/``np.insert``, so the recorded
``thread_overlap_speedup`` is the re-measured overlap of that path; the
number is recorded precisely so the trade-off stays visible either way.

Reported rows: ``write_queue/group{g}`` (µs per committed row) and
``write_queue/parallel_merge`` (threaded flushes, for the overlap
ratio). The queries/sec-style ``*_rows_per_sec`` keys feed the CI
regression gate (``scripts/bench_gate.py``).
"""

from __future__ import annotations

import time

import numpy as np

from repro.core import HREngine
from repro.core.tpch import generate_simulation

from .common import record

LAYOUTS = [("k0", "k1", "k2"), ("k1", "k2", "k0"), ("k2", "k0", "k1")]


def _pending_batches(rng, schema, n_batches, batch_rows):
    out = []
    for _ in range(n_batches):
        kc = {
            c: rng.integers(0, schema.max_value(c) + 1, batch_rows).astype(np.int64)
            for c in ("k0", "k1", "k2")
        }
        vc = {"metric": rng.uniform(0, 1, batch_rows)}
        out.append((kc, vc))
    return out


def _fresh_engine(kc, vc, schema, *, memtable_rows=0):
    eng = HREngine(n_nodes=4, memtable_rows=memtable_rows)
    eng.create_column_family(
        "cf", kc, vc, replication_factor=3, layouts=LAYOUTS, schema=schema,
    )
    return eng


def _drain(eng, queue, *, parallel=False):
    for gk, gv in queue:
        eng.write("cf", gk, gv, parallel=parallel)
    eng.flush_memtables("cf", parallel=parallel)  # leftover staged rows


def run(
    n_rows: int = 60_000,
    n_batches: int = 16,
    batch_rows: int = 2_000,
    group_sizes=(1, 4, 16),
    seed: int = 0,
    repeats: int = 3,
) -> dict:
    rng = np.random.default_rng(seed)
    kc, vc, schema = generate_simulation(n_rows, 3, seed=seed)
    queue = _pending_batches(rng, schema, n_batches, batch_rows)
    total_rows = n_batches * batch_rows

    def _timed_drain(g: int, parallel: bool) -> float:
        # best-of-N full drains, each from a fresh base state: the
        # rows/sec feed the 30% CI regression gate, and at smoke scale
        # a single drain is a few milliseconds — one scheduler hiccup
        # must not fail the gate (same rationale as the batched gate)
        walls = []
        for _ in range(repeats):
            eng = _fresh_engine(kc, vc, schema, memtable_rows=g * batch_rows)
            t0 = time.perf_counter()
            _drain(eng, queue, parallel=parallel)
            walls.append(time.perf_counter() - t0)
        return min(walls)

    out: dict = {"n_rows": n_rows, "batch_rows": batch_rows, "n_batches": n_batches}
    for g in group_sizes:
        # same base state per size; the staging threshold IS the group
        # size — every g-th write crosses it and flushes the group
        wall = _timed_drain(g, parallel=False)
        rps = total_rows / max(wall, 1e-12)
        out[f"group{g}_rows_per_sec"] = rps
        record(f"write_queue/group{g}", wall / total_rows * 1e6, f"rows_per_s={rps:.0f}")

    # threaded-vs-sequential overlap of the per-replica flushes: drain
    # the queue at the largest group size with write(parallel=True)
    g = max(group_sizes)
    wall_par = _timed_drain(g, parallel=True)
    rps_par = total_rows / max(wall_par, 1e-12)
    out["parallel_merge_rows_per_sec"] = rps_par
    out["thread_overlap_speedup"] = rps_par / out[f"group{g}_rows_per_sec"]
    record(
        "write_queue/parallel_merge", wall_par / total_rows * 1e6,
        f"rows_per_s={rps_par:.0f};thread_speedup={out['thread_overlap_speedup']:.2f}x",
    )
    return out


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--rows", type=int, default=60_000)
    ap.add_argument("--batches", type=int, default=16)
    ap.add_argument("--batch-rows", type=int, default=2_000)
    args = ap.parse_args()
    for k, v in run(
        n_rows=args.rows, n_batches=args.batches, batch_rows=args.batch_rows
    ).items():
        print(k, v)
