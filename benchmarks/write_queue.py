"""Group-commit write queue — §5.3 writes at batch granularity.

Client writes arrive as small batches; a write queue that commits them
in groups amortizes the per-replica merge overhead (one merge of
``g × b`` rows instead of ``g`` merges of ``b`` rows; each replica
still sorts its own copy — paper Table 1). This benchmark drains the
same queue of ``n_batches`` pending batches at several group-commit
sizes and reports committed rows/sec.

It also measures ``HREngine.write(parallel=True)`` — the thread-pool
overlap of the independent per-replica merge sorts — against the
sequential default at the largest group size. On CPython the merge is
dominated by ``np.argsort``/``np.insert``, which hold the GIL, so the
recorded ``thread_overlap_speedup`` hovers near (or below) 1.0; the
number is recorded precisely so the trade-off stays visible, and group
commit is the mechanism that actually amortizes.

Reported rows: ``write_queue/group{g}`` (µs per committed row) and
``write_queue/parallel_merge`` (threaded writes, for the overlap ratio).
"""

from __future__ import annotations

import time

import numpy as np

from repro.core import HREngine
from repro.core.tpch import generate_simulation

from .common import record

LAYOUTS = [("k0", "k1", "k2"), ("k1", "k2", "k0"), ("k2", "k0", "k1")]


def _pending_batches(rng, schema, n_batches, batch_rows):
    out = []
    for _ in range(n_batches):
        kc = {
            c: rng.integers(0, schema.max_value(c) + 1, batch_rows).astype(np.int64)
            for c in ("k0", "k1", "k2")
        }
        vc = {"metric": rng.uniform(0, 1, batch_rows)}
        out.append((kc, vc))
    return out


def _fresh_engine(kc, vc, schema):
    eng = HREngine(n_nodes=4)
    eng.create_column_family(
        "cf", kc, vc, replication_factor=3, layouts=LAYOUTS, schema=schema,
    )
    return eng


def _concat(group):
    kc = {c: np.concatenate([b[0][c] for b in group]) for c in group[0][0]}
    vc = {c: np.concatenate([b[1][c] for b in group]) for c in group[0][1]}
    return kc, vc


def run(
    n_rows: int = 60_000,
    n_batches: int = 16,
    batch_rows: int = 2_000,
    group_sizes=(1, 4, 16),
    seed: int = 0,
) -> dict:
    rng = np.random.default_rng(seed)
    kc, vc, schema = generate_simulation(n_rows, 3, seed=seed)
    queue = _pending_batches(rng, schema, n_batches, batch_rows)
    total_rows = n_batches * batch_rows

    out: dict = {"n_rows": n_rows, "batch_rows": batch_rows, "n_batches": n_batches}
    for g in group_sizes:
        eng = _fresh_engine(kc, vc, schema)  # same base state per size
        t0 = time.perf_counter()
        for s in range(0, n_batches, g):
            gk, gv = _concat(queue[s : s + g])
            eng.write("cf", gk, gv)
        wall = time.perf_counter() - t0
        rps = total_rows / max(wall, 1e-12)
        out[f"group{g}_rows_per_sec"] = rps
        record(f"write_queue/group{g}", wall / total_rows * 1e6, f"rows_per_s={rps:.0f}")

    # threaded-vs-sequential overlap of the per-replica merges: drain
    # the queue at the largest group size with write(parallel=True)
    g = max(group_sizes)
    eng = _fresh_engine(kc, vc, schema)
    t0 = time.perf_counter()
    for s in range(0, n_batches, g):
        gk, gv = _concat(queue[s : s + g])
        eng.write("cf", gk, gv, parallel=True)
    wall_par = time.perf_counter() - t0
    rps_par = total_rows / max(wall_par, 1e-12)
    out["parallel_merge_rows_per_sec"] = rps_par
    out["thread_overlap_speedup"] = rps_par / out[f"group{g}_rows_per_sec"]
    record(
        "write_queue/parallel_merge", wall_par / total_rows * 1e6,
        f"rows_per_s={rps_par:.0f};thread_speedup={out['thread_overlap_speedup']:.2f}x",
    )
    return out


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--rows", type=int, default=60_000)
    ap.add_argument("--batches", type=int, default=16)
    ap.add_argument("--batch-rows", type=int, default=2_000)
    args = ap.parse_args()
    for k, v in run(
        n_rows=args.rows, n_batches=args.batches, batch_rows=args.batch_rows
    ).items():
        print(k, v)
