"""Open-loop serving latency through the front door.

The headline serving metric: p50/p99 request latency and shed/degrade
rates versus offered load. A seeded Poisson arrival stream is pushed
through :class:`~repro.serving.frontdoor.FrontDoor` at fractions of
the engine's measured closed-loop capacity; latencies are virtual-time
(arrival → completion on the front door's discrete-event clock), so
queue waits are included — the quantity a client actually observes,
not the engine's per-call wall.

Three sections:

* **Passthrough** — every request arrives at t=0 with admission guards
  open, so the front door degenerates to batched ``read_many`` calls;
  its q/s against the direct closed-loop ``read_many`` q/s prices the
  batching layer itself (the acceptance bar: within 15%).
* **Load sweep** — offered load at 0.25×/1×/2× capacity. Below
  saturation p99 tracks service time; past it, deadlines and the
  degradation ladder must hold p99 near the budget while shed/degrade
  rates climb — *bounded* latency, explicit refusals, no unbounded
  queue.
* **Gate keys** — ``passthrough_qps`` / ``direct_qps`` (higher is
  better) and the per-load ``*_p99_us`` (lower is better) feed
  ``scripts/bench_gate.py``; shed/degrade/ok rates ride along as
  descriptive keys.

A third interleaved passthrough leg runs with a live
:class:`~repro.obs.trace.Tracer` attached: ``trace_overhead`` is the
median within-pair traced/untraced wall ratio minus one (the price of
recording every span), gated absolutely by ``scripts/bench_gate.py``;
``stage_breakdown`` is the trace-derived per-stage wall table
(``repro.obs.export.stage_totals`` over the final traced run) and
rides along un-gated. The *untraced* leg exercises the tracing-off
fast path (``trace is None`` no-ops), so the ``passthrough_qps`` gate
against the recorded baseline is what enforces the
instrumentation-off budget.
"""

from __future__ import annotations

import time

import numpy as np

from repro.core import HREngine, QUORUM, random_workload
from repro.core.tpch import generate_simulation
from repro.obs import Tracer, stage_totals
from repro.serving.frontdoor import FrontDoor, Request

from .common import record

LAYOUTS = [("k0", "k1", "k2"), ("k1", "k2", "k0"), ("k2", "k0", "k1")]
_CF = "cf"


def _build(kc, vc, schema, *, partitions):
    # result cache off: serving latency must price actual scans, not
    # repeat-hit lookups of a benchmark's recycled queries
    eng = HREngine(n_nodes=6, result_cache=False)
    eng.create_column_family(
        _CF, kc, vc, replication_factor=3, layouts=LAYOUTS,
        schema=schema, partitions=partitions,
    )
    return eng


def _percentiles_us(latencies_s):
    lat = np.asarray(latencies_s)
    return (
        float(np.percentile(lat, 50) * 1e6),
        float(np.percentile(lat, 99) * 1e6),
    )


def run(
    n_rows: int = 120_000,
    batch: int = 64,
    n_requests: int = 400,
    loads: tuple[float, ...] = (0.25, 1.0, 2.0),
    deadline_s: float = 50e-3,
    quorum_frac: float = 0.25,
    partitions: int = 4,
    repeats: int = 5,
    best: bool = False,
    seed: int = 0,
) -> dict:
    kc, vc, schema = generate_simulation(n_rows, 3, seed=seed)
    rng = np.random.default_rng(seed + 1)
    eng = _build(kc, vc, schema, partitions=partitions)
    queries = list(
        random_workload(rng, schema, list(kc), n_requests).queries
    )
    out: dict = {}

    # -- closed-loop capacity vs zero-load passthrough ----------------------
    # all arrivals at t=0 with guards open: continuous batching
    # degenerates to the same full read_many batches, so the q/s gap is
    # the front-door layer's own tax. The two are timed INTERLEAVED,
    # one pair per repeat: clock-frequency drift between two separate
    # timing blocks otherwise dwarfs the tax being measured.
    def direct():
        for i in range(0, len(queries), batch):
            eng.read_many(_CF, queries[i : i + batch])

    pass_reqs = [Request(_CF, q) for q in queries]

    # one front door per flavor, reused across repeats with a registry
    # reset between runs (the reset_stats() contract) so allocation
    # stays out of the timed region; the traced door records every
    # request into fresh span trees per repeat
    fd_plain = FrontDoor(
        eng, max_batch=batch, max_wait=1e-3,
        max_queue=n_requests, shed_fill=1.0,
    )
    tracer = Tracer()
    fd_traced = FrontDoor(
        eng, max_batch=batch, max_wait=1e-3,
        max_queue=n_requests, shed_fill=1.0, tracer=tracer,
    )

    def passthrough(fd):
        fd.reset_stats()
        t0 = time.perf_counter()
        resps = fd.serve(pass_reqs)
        wall = time.perf_counter() - t0
        assert all(r.ok for r in resps)
        return wall

    ts_direct, ts_pass, ts_traced = [], [], []
    for _ in range(repeats):
        t0 = time.perf_counter()
        direct()
        ts_direct.append(time.perf_counter() - t0)
        ts_pass.append(passthrough(fd_plain))
        tracer.clear()
        ts_traced.append(passthrough(fd_traced))
    agg = min if best else (lambda xs: float(np.median(xs)))
    t_direct, t_pass = agg(ts_direct), agg(ts_pass)
    direct_qps = n_requests / max(t_direct, 1e-12)
    out["direct_qps"] = direct_qps
    record("serving/direct_read_many", t_direct * 1e6, f"{direct_qps:,.0f} q/s")
    pass_qps = n_requests / max(t_pass, 1e-12)
    # overhead from WITHIN-pair ratios: each repeat's passthrough is
    # divided by the direct run adjacent to it in time, so slow drift
    # (thermal/frequency) cancels instead of masquerading as tax; the
    # MEDIAN pair (never the min — a ratio's min is biased fast) is
    # the representative number
    overhead = float(
        np.median([p / max(d, 1e-12) for p, d in zip(ts_pass, ts_direct)])
    ) - 1.0
    out["passthrough_qps"] = pass_qps
    out["passthrough_overhead"] = overhead
    record(
        "serving/frontdoor_passthrough", t_pass * 1e6,
        f"{pass_qps:,.0f} q/s (overhead {overhead * 100:+.1f}%)",
    )
    # instrumentation tax: traced vs untraced passthrough, within-pair
    # ratios for the same drift-cancellation reason as above
    t_traced = agg(ts_traced)
    trace_overhead = float(
        np.median([t / max(p, 1e-12) for t, p in zip(ts_traced, ts_pass)])
    ) - 1.0
    out["trace_overhead"] = trace_overhead
    record(
        "serving/frontdoor_traced", t_traced * 1e6,
        f"{n_requests / max(t_traced, 1e-12):,.0f} q/s "
        f"(trace overhead {trace_overhead * 100:+.1f}%)",
    )
    # per-stage wall breakdown from the final traced run (descriptive,
    # un-gated): where a request's time actually goes
    out["stage_breakdown"] = {
        name: {"count": int(row["count"]), "total_s": float(row["total"])}
        for name, row in stage_totals(tracer.roots).items()
    }

    # -- open-loop sweep: Poisson arrivals at fractions of capacity ---------
    # each sweep's queue buildup depends on the ratio of the engine's
    # speed DURING the sweep to the capacity measured above, so a
    # single shot is hostage to transient machine load; the sweep runs
    # `repeats` times (fresh arrival draws + fresh FrontDoor) and the
    # gated p99 is the median across runs
    for frac in loads:
        rate = frac * direct_qps
        p50s, p99s = [], []
        n_total = n_ok = n_degraded = 0
        max_depth = 0
        for _ in range(repeats):
            arrivals = np.cumsum(rng.exponential(1.0 / rate, n_requests))
            reqs = [
                Request(
                    _CF,
                    q,
                    arrival_s=float(arrivals[i]),
                    deadline_s=deadline_s,
                    priority=int(rng.integers(0, 3)),
                    consistency=QUORUM if rng.random() < quorum_frac else "ONE",
                )
                for i, q in enumerate(queries)
            ]
            fd = FrontDoor(eng, max_batch=batch, max_wait=2e-3, max_queue=256)
            resps = fd.serve(reqs)
            s = fd.stats
            ok = [r for r in resps if r.ok]
            n_total += n_requests
            n_ok += len(ok)
            n_degraded += s["consistency_degraded"]
            max_depth = max(max_depth, s["max_queue_depth"])
            if ok:
                p50, p99 = _percentiles_us([r.latency_s for r in ok])
                p50s.append(p50)
                p99s.append(p99)
        if not p99s:
            # a machine slow enough to shed everything still reports —
            # log the degenerate sweep instead of crashing the gate run
            record(f"serving/load_{frac:g}x", 0.0, "no request survived")
            continue
        # best=True (smoke/CI) gates the MIN across sweep runs: ambient
        # machine load only ever inflates a sweep's tail, so the min is
        # the clean-machine tail — same best-of-N rationale as the
        # throughput gates; the median is the honest default elsewhere
        p50_us = float(agg(p50s))
        p99_us = float(agg(p99s))
        shed_rate = (n_total - n_ok) / n_total
        degrade_rate = n_degraded / n_total
        label = f"{frac:g}x"
        out[f"load_{label}"] = {
            "offered_rate": rate,  # an input, not a result: keep un-gated
            "p50_us": p50_us,
            "p99_us": p99_us,
            "ok_rate": n_ok / n_total,
            "shed_rate": shed_rate,
            "degrade_rate": degrade_rate,
            "max_queue_depth": max_depth,
        }
        record(
            f"serving/load_{label}", p99_us,
            f"p50={p50_us:,.0f}us p99={p99_us:,.0f}us "
            f"shed={shed_rate * 100:.0f}% "
            f"degraded={degrade_rate * 100:.0f}%",
        )
    return out


if __name__ == "__main__":
    print(run())
