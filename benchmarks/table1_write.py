"""Table 1 — write/load throughput, TR vs HR (claim C4: identical ±1%).

Both mechanisms run every batch through the durable write path (commit
log append → per-replica memtable → one sorted-run flush per replica in
its own order), so HR costs the same writes as TR: the log append is
layout-agnostic and shared, and each replica sorts exactly one copy. We
bulk-load in batches and time the full load including the final
memtable drain, so staged rows can't flatter either mechanism.
"""

from __future__ import annotations

import numpy as np

from repro.core import HREngine, random_workload
from repro.core.tpch import generate_orders, orders_schema, q1_q2_workload
from .common import record, time_fn


def run(total_rows=(40_000, 80_000, 120_000), batch_rows: int = 10_000,
        rf: int = 3, seed: int = 0) -> dict:
    out = {}
    for n in total_rows:
        wl = q1_q2_workload(50, seed=seed, n_rows=n)
        kc, vc = generate_orders(n / 1.5e6, seed=seed)
        # split into load batches
        times = {}
        for mech in ("tr", "hr"):
            eng = HREngine(n_nodes=6)
            seed_rows = max(1, batch_rows // 10)
            eng.create_column_family(
                mech, {k: v[:seed_rows] for k, v in kc.items()},
                {k: v[:seed_rows] for k, v in vc.items()},
                replication_factor=rf, mechanism=mech.upper(), workload=wl,
                schema=orders_schema(),
            )
            import time as _t

            t0 = _t.perf_counter()
            for lo in range(seed_rows, n, batch_rows):
                hi = min(lo + batch_rows, n)
                eng.write(mech, {k: v[lo:hi] for k, v in kc.items()},
                          {k: v[lo:hi] for k, v in vc.items()})
            eng.flush_memtables(mech)  # drain anything still staged
            times[mech] = _t.perf_counter() - t0
        ratio = times["hr"] / max(times["tr"], 1e-12)
        record(f"table1/load_{n}_tr", times["tr"] * 1e6, "")
        record(f"table1/load_{n}_hr", times["hr"] * 1e6, f"hr/tr={ratio:.3f}")
        out[n] = {"tr_s": times["tr"], "hr_s": times["hr"], "ratio": ratio}
    return out


if __name__ == "__main__":
    for n, r in run().items():
        print(n, r)
