"""Shared benchmark helpers: timing + CSV rows (name,us_per_call,derived)."""

from __future__ import annotations

import time

import numpy as np

ROWS: list[tuple[str, float, str]] = []


def record(name: str, us_per_call: float, derived: str = "") -> None:
    ROWS.append((name, us_per_call, derived))
    print(f"{name},{us_per_call:.2f},{derived}")


def time_fn(
    fn, *args, repeats: int = 3, best: bool = False, **kwargs
) -> tuple[float, object]:
    """Median wall seconds (or best-of-N with ``best=True``) + last result.

    ``best=True`` is for the CI regression gate: at smoke scale a single
    call is sub-millisecond, and the *minimum* over N repeats is far less
    sensitive to scheduler jitter than the median, which is what lets the
    gate hold a 30% tolerance on a shared machine.
    """
    ts, out = [], None
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn(*args, **kwargs)
        ts.append(time.perf_counter() - t0)
    return float(min(ts) if best else np.median(ts)), out


def flush_csv(path: str | None = None) -> None:
    if path:
        with open(path, "w") as f:
            f.write("name,us_per_call,derived\n")
            for n, u, d in ROWS:
                f.write(f"{n},{u:.2f},{d}\n")
