"""Shared benchmark helpers: timing + CSV rows (name,us_per_call,derived)."""

from __future__ import annotations

import time

import numpy as np

ROWS: list[tuple[str, float, str]] = []


def record(name: str, us_per_call: float, derived: str = "") -> None:
    ROWS.append((name, us_per_call, derived))
    print(f"{name},{us_per_call:.2f},{derived}")


def time_fn(fn, *args, repeats: int = 3, **kwargs) -> tuple[float, object]:
    """Median wall seconds + last result."""
    ts, out = [], None
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn(*args, **kwargs)
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts)), out


def flush_csv(path: str | None = None) -> None:
    if path:
        with open(path, "w") as f:
            f.write("name,us_per_call,derived\n")
            for n, u, d in ROWS:
                f.write(f"{n},{u:.2f},{d}\n")
