"""§3.2 — HRCA convergence: 'generally converges in ten seconds'.

Paper-scale instance: 6 clustering keys, RF=3, 500 queries. We report
wall time and the accepted-cost trace decile positions.
"""

from __future__ import annotations

import numpy as np

from repro.core import CostModel, hrca, initial_state, random_workload
from repro.core.ecdf import TableStats
from repro.core.tpch import generate_simulation
from .common import record


def run(n_rows: int = 500_000, n_keys: int = 6, rf: int = 3,
        n_queries: int = 500, k_max: int = 3000, seed: int = 0) -> dict:
    kc, vc, schema = generate_simulation(n_rows, n_keys, seed=seed)
    stats = TableStats.from_columns(kc, schema)
    model = CostModel(stats=stats)
    rng = np.random.default_rng(seed + 1)
    wl = random_workload(rng, schema, list(kc), n_queries)
    res = hrca(model, wl, initial_state(tuple(kc), rf), k_max=k_max, seed=0)
    improve = res.initial_cost / max(res.cost, 1e-12)
    record("hrca/wall_seconds", res.wall_seconds * 1e6,
           f"improve={improve:.1f}x;steps={res.n_steps};accepted={res.n_accepted}")
    # time-to-90%-of-final-improvement
    trace = np.asarray(res.trace)
    target = res.initial_cost - 0.9 * (res.initial_cost - res.cost)
    hit = int(np.argmax(trace <= target)) if (trace <= target).any() else len(trace)
    record("hrca/steps_to_90pct", float(hit), "")
    # prorated wall-clock to 90% improvement (the paper's "converges in
    # ten seconds" is about convergence, not the full annealing budget)
    wall_90 = res.wall_seconds * hit / max(len(trace), 1)
    record("hrca/wall_to_90pct", wall_90 * 1e6, f"<10s claim: {'OK' if wall_90 < 10 else 'MISS'}")
    return {
        "wall_seconds": res.wall_seconds,
        "improvement": improve,
        "steps_to_90pct": hit,
        "final_layouts": [list(a) for a in res.layouts],
    }


if __name__ == "__main__":
    print(run())
