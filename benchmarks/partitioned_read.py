"""Scatter-gather read throughput vs partition count (token ring).

A partitioned column family (``create_column_family(partitions=P)``,
PR 5) answers ``read_many`` by intersecting each query's canonical slab
bounds with the ring's token ranges, executing one grouped scan per
``(partition, replica)``, and merging partial aggregates on the host.
This benchmark drains the same query batches against the same dataset
at several partition counts and reports queries/sec:

* queries with an equality on the leading canonical key are pinned to a
  single partition — the Cassandra point-read case;
* leading-key ranges span a few partitions;
* residual-only filters fan out to every partition — the worst case,
  paying P grouped scans for one query.

What partitioning buys is *distribution*: per-node table state shrinks
to ~N/P, writes fan out to the owning partitions only, and recovery
rebuilds one partition slice instead of the whole keyspace. It does
NOT reduce total rows scanned on this single-host simulation — the
Cost Evaluator already routes every query to a slab-optimal layout, so
the per-P numbers chiefly record the scatter/gather planning overhead,
which this gate keeps honest (and bounded) per partition count.
``p1`` doubles as the regression anchor for the unpartitioned path.
The ``p{P}_qps`` keys feed the CI regression gate
(``scripts/bench_gate.py``) alongside the batched-read queries/sec;
the result cache is disabled so repeats measure the storage path, not
the cache.
"""

from __future__ import annotations

import numpy as np

from repro.core import Eq, HREngine, Query, Range
from repro.core.tpch import generate_simulation

from .common import record, time_fn

LAYOUTS = [("k0", "k1", "k2"), ("k1", "k2", "k0"), ("k2", "k0", "k1")]


def _mixed_batch(rng, schema, batch):
    """~40% single-partition equalities, ~30% leading-key range spans,
    ~30% full fan-out residual filters, mixed count/sum aggs."""
    qs = []
    doms = {c: schema.max_value(c) + 1 for c in ("k0", "k1", "k2")}
    for i in range(batch):
        u = rng.random()
        if u < 0.4:
            f = {"k0": Eq(int(rng.integers(0, doms["k0"])))}
        elif u < 0.7:
            lo = int(rng.integers(0, doms["k0"] - 1))
            width = max(1, doms["k0"] // 8)
            f = {"k0": Range(lo, min(lo + width, doms["k0"]))}
        else:
            lo = int(rng.integers(0, doms["k1"] - 1))
            f = {"k1": Range(lo, min(lo + 2, doms["k1"]))}
        agg = "sum" if i % 2 else "count"
        qs.append(
            Query(filters=f, agg=agg, value_col="metric" if agg == "sum" else None)
        )
    return qs


def run(
    n_rows: int = 200_000,
    batch: int = 64,
    n_batches: int = 4,
    partition_counts=(1, 2, 4, 8),
    seed: int = 0,
    repeats: int = 3,
    best: bool = False,
) -> dict:
    rng = np.random.default_rng(seed)
    kc, vc, schema = generate_simulation(n_rows, 3, seed=seed)
    batches = [_mixed_batch(rng, schema, batch) for _ in range(n_batches)]
    total_q = batch * n_batches
    out: dict = {"n_rows": n_rows, "batch": batch, "n_batches": n_batches}

    for p in partition_counts:
        # cache off: repeated drains must measure the scatter-gather
        # storage path, not result-cache hits (same as the fig5 benches)
        eng = HREngine(n_nodes=8, result_cache=False)
        eng.create_column_family(
            "cf", kc, vc, replication_factor=3, layouts=LAYOUTS, schema=schema,
            partitions=p,
        )

        def drain():
            # returns the drain's total rows_scanned so the derived
            # column comes from a timed pass (no extra untimed drain)
            return sum(
                rep.rows_scanned
                for qs in batches
                for _, rep in eng.read_many("cf", qs)
            )

        wall, rows = time_fn(drain, repeats=repeats, best=best)
        qps = total_q / max(wall, 1e-12)
        out[f"p{p}_qps"] = qps
        record(
            f"partitioned_read/p{p}",
            wall / total_q * 1e6,
            f"qps={qps:.0f};rows_scanned={rows}",
        )
    return out


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--rows", type=int, default=200_000)
    ap.add_argument("--batch", type=int, default=64)
    ap.add_argument("--partitions", type=int, nargs="+", default=[1, 2, 4, 8])
    args = ap.parse_args()
    for k, v in run(
        n_rows=args.rows, batch=args.batch, partition_counts=tuple(args.partitions)
    ).items():
        print(k, v)
