"""Scatter-gather read throughput vs partition count (token ring).

A partitioned column family (``create_column_family(partitions=P)``,
PR 5) answers ``read_many`` by intersecting each query's canonical slab
bounds with the ring's token ranges, executing one grouped scan per
``(partition, replica)``, and merging partial aggregates on the host.
This benchmark drains the same query batches against the same dataset
at several partition counts and reports queries/sec:

* queries with an equality on the leading canonical key are pinned to a
  single partition — the Cassandra point-read case;
* leading-key ranges span a few partitions;
* residual-only filters fan out to every partition — the worst case,
  paying P grouped scans for one query.

What partitioning buys is *distribution*: per-node table state shrinks
to ~N/P, writes fan out to the owning partitions only, and recovery
rebuilds one partition slice instead of the whole keyspace. It does
NOT reduce total rows scanned on this single-host simulation — the
Cost Evaluator already routes every query to a slab-optimal layout, so
the per-P numbers chiefly record the scatter/gather planning overhead,
which this gate keeps honest (and bounded) per partition count.
``p1`` doubles as the regression anchor for the unpartitioned path.
The ``p{P}_qps`` keys feed the CI regression gate
(``scripts/bench_gate.py``) alongside the batched-read queries/sec;
the result cache is disabled so repeats measure the storage path, not
the cache.

The ``--skew`` section (PR 6) is the vnode-ring rebalance exercise: a
Zipf(``a``)-skewed keyspace is created at ``skew_partitions`` equal
token splits — piling most rows into the low-token partitions — then
``HREngine.rebalance()`` moves the boundaries to the observed token
quantiles. Reported: per-partition max/mean row imbalance before and
after, rows migrated, and the post-rebalance drain throughput
(``p{P}_skew_qps``, gated like the uniform keys).
"""

from __future__ import annotations

import numpy as np

from repro.core import Eq, HREngine, KeySchema, Query, Range
from repro.core.tpch import generate_simulation

from .common import record, time_fn

LAYOUTS = [("k0", "k1", "k2"), ("k1", "k2", "k0"), ("k2", "k0", "k1")]


def _zipf_keys(rng, n_rows: int, bits: int, a: float) -> np.ndarray:
    """Zipf(a) keys clipped into [0, 2**bits) — mass piles at 0."""
    dom = 1 << bits
    return np.minimum(rng.zipf(a, n_rows), dom) - 1


def _mixed_batch(rng, schema, batch):
    """~40% single-partition equalities, ~30% leading-key range spans,
    ~30% full fan-out residual filters, mixed count/sum aggs."""
    qs = []
    doms = {c: schema.max_value(c) + 1 for c in ("k0", "k1", "k2")}
    for i in range(batch):
        u = rng.random()
        if u < 0.4:
            f = {"k0": Eq(int(rng.integers(0, doms["k0"])))}
        elif u < 0.7:
            lo = int(rng.integers(0, doms["k0"] - 1))
            width = max(1, doms["k0"] // 8)
            f = {"k0": Range(lo, min(lo + width, doms["k0"]))}
        else:
            lo = int(rng.integers(0, doms["k1"] - 1))
            f = {"k1": Range(lo, min(lo + 2, doms["k1"]))}
        agg = "sum" if i % 2 else "count"
        qs.append(
            Query(filters=f, agg=agg, value_col="metric" if agg == "sum" else None)
        )
    return qs


def run(
    n_rows: int = 200_000,
    batch: int = 64,
    n_batches: int = 4,
    partition_counts=(1, 2, 4, 8),
    seed: int = 0,
    repeats: int = 3,
    best: bool = False,
    skew: float | None = None,
    skew_partitions: int = 8,
) -> dict:
    rng = np.random.default_rng(seed)
    kc, vc, schema = generate_simulation(n_rows, 3, seed=seed)
    batches = [_mixed_batch(rng, schema, batch) for _ in range(n_batches)]
    total_q = batch * n_batches
    out: dict = {"n_rows": n_rows, "batch": batch, "n_batches": n_batches}

    for p in partition_counts:
        # cache off: repeated drains must measure the scatter-gather
        # storage path, not result-cache hits (same as the fig5 benches)
        eng = HREngine(n_nodes=8, result_cache=False)
        eng.create_column_family(
            "cf", kc, vc, replication_factor=3, layouts=LAYOUTS, schema=schema,
            partitions=p,
        )

        def drain():
            # returns the drain's total rows_scanned so the derived
            # column comes from a timed pass (no extra untimed drain)
            return sum(
                rep.rows_scanned
                for qs in batches
                for _, rep in eng.read_many("cf", qs)
            )

        wall, rows = time_fn(drain, repeats=repeats, best=best)
        qps = total_q / max(wall, 1e-12)
        out[f"p{p}_qps"] = qps
        record(
            f"partitioned_read/p{p}",
            wall / total_q * 1e6,
            f"qps={qps:.0f};rows_scanned={rows}",
        )

    if skew:
        out.update(
            _run_skew(
                n_rows=n_rows,
                batch=batch,
                n_batches=n_batches,
                partitions=skew_partitions,
                a=skew,
                seed=seed,
                repeats=repeats,
                best=best,
            )
        )
    return out


def _run_skew(
    *,
    n_rows: int,
    batch: int,
    n_batches: int,
    partitions: int,
    a: float,
    seed: int,
    repeats: int,
    best: bool,
) -> dict:
    """Zipf-skewed keyspace: equal splits → measure imbalance →
    ``rebalance()`` → measure again, then drain the mixed batches on
    the balanced ring."""
    rng = np.random.default_rng(seed + 1)
    bits = 10
    schema = KeySchema({"k0": bits, "k1": bits, "k2": bits})
    kc = {f"k{i}": _zipf_keys(rng, n_rows, bits, a) for i in range(3)}
    vc = {"metric": rng.random(n_rows)}
    batches = [_mixed_batch(rng, schema, batch) for _ in range(n_batches)]
    total_q = batch * n_batches

    eng = HREngine(n_nodes=8, result_cache=False)
    eng.create_column_family(
        "cf", kc, vc, replication_factor=3, layouts=LAYOUTS, schema=schema,
        partitions=partitions,
    )
    imb_before = eng.partition_imbalance("cf")
    rb = eng.rebalance("cf")

    def drain():
        return sum(
            rep.rows_scanned
            for qs in batches
            for _, rep in eng.read_many("cf", qs)
        )

    wall, rows = time_fn(drain, repeats=repeats, best=best)
    qps = total_q / max(wall, 1e-12)
    record(
        f"partitioned_read/p{partitions}_skew",
        wall / total_q * 1e6,
        f"qps={qps:.0f};imb={imb_before:.2f}->{rb['imbalance_after']:.2f}"
        f";moved={rb['rows_moved']};rows_scanned={rows}",
    )
    return {
        "skew_a": a,
        "skew_imbalance_before": imb_before,
        "skew_imbalance_after": rb["imbalance_after"],
        "skew_rows_moved": rb["rows_moved"],
        f"p{partitions}_skew_qps": qps,
    }


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--rows", type=int, default=200_000)
    ap.add_argument("--batch", type=int, default=64)
    ap.add_argument("--partitions", type=int, nargs="+", default=[1, 2, 4, 8])
    ap.add_argument(
        "--skew", type=float, default=None,
        help="Zipf exponent for the skewed rebalance section (e.g. 1.3)",
    )
    ap.add_argument("--skew-partitions", type=int, default=8)
    args = ap.parse_args()
    for k, v in run(
        n_rows=args.rows,
        batch=args.batch,
        partition_counts=tuple(args.partitions),
        skew=args.skew,
        skew_partitions=args.skew_partitions,
    ).items():
        print(k, v)
