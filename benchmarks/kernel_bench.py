"""Kernel micro-benchmarks: Pallas scan_agg / ecdf_hist vs jnp oracle.

On CPU the Pallas kernels run in interpret mode (pure-Python executor),
so wall-clock here only validates plumbing; the TPU-relevant numbers are
the per-call bytes (Row()·row_bytes — the quantity Eq (1) prices).
"""

from __future__ import annotations

import jax
import numpy as np

from repro.kernels import ecdf_hist, ecdf_hist_ref, scan_agg, scan_agg_ref
from .common import record, time_fn


def run(n_rows: int = 200_000, n_keys: int = 4, seed: int = 0) -> dict:
    rng = np.random.default_rng(seed)
    keys = rng.integers(0, 1024, (n_keys, n_rows)).astype(np.int32)
    vals = rng.uniform(0, 1, n_rows).astype(np.float32)
    lo = np.zeros(n_keys, np.int32)
    hi = np.full(n_keys, 512, np.int32)
    slab = np.array([0, n_rows], np.int32)

    import jax.numpy as jnp

    args = (jnp.asarray(keys), jnp.asarray(vals), jnp.asarray(lo), jnp.asarray(hi),
            jnp.asarray(slab))
    ref = jax.jit(scan_agg_ref)
    t_ref, _ = time_fn(lambda: jax.block_until_ready(ref(*args)), repeats=5)
    record("kernel/scan_agg_ref_jit", t_ref * 1e6,
           f"bytes={(keys.nbytes + vals.nbytes)};rows={n_rows}")

    t_pl, _ = time_fn(lambda: jax.block_until_ready(scan_agg(*args)), repeats=1)
    record("kernel/scan_agg_pallas_interp", t_pl * 1e6, "interpret-mode (CPU)")

    col = rng.integers(0, 4096, n_rows).astype(np.int32)
    refh = jax.jit(lambda c: ecdf_hist_ref(c, n_bins=1024, bin_width=4))
    t_rh, _ = time_fn(lambda: jax.block_until_ready(refh(jnp.asarray(col))), repeats=5)
    record("kernel/ecdf_hist_ref_jit", t_rh * 1e6, f"rows={n_rows}")
    return {"scan_ref_us": t_ref * 1e6, "scan_pallas_us": t_pl * 1e6}


if __name__ == "__main__":
    print(run())
