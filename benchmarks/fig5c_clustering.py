"""Fig 5c/5f — latency vs number of clustering keys (RF=3).

Paper claim (C3): the HR gain grows with the number of clustering keys
(more permutations to specialize over); with 2–3 keys three replicas are
under-utilized.
"""

from __future__ import annotations

import numpy as np

from repro.core import HREngine, random_workload
from repro.core.tpch import generate_simulation
from .common import record


def run(n_rows: int = 300_000, key_counts=(2, 3, 4, 5, 6), rf: int = 3,
        n_queries: int = 60, seed: int = 0) -> dict:
    out = {}
    for nk in key_counts:
        kc, vc, schema = generate_simulation(n_rows, nk, seed=seed + nk)
        rng = np.random.default_rng(seed + 100 + nk)
        wl = random_workload(rng, schema, list(kc), n_queries, value_col="metric")
        # no result cache: duplicate workload queries must pay the scan,
        # or the paper's latency figures deflate
        eng = HREngine(n_nodes=6, result_cache=False)
        eng.create_column_family("tr", kc, vc, replication_factor=rf,
                                 mechanism="TR", workload=wl, schema=schema)
        eng.create_column_family("hr", kc, vc, replication_factor=rf,
                                 mechanism="HR", workload=wl, schema=schema,
                                 hrca_kwargs={"k_max": 3000, "seed": 0})
        res = {}
        for mech in ("tr", "hr"):
            wall = rows = 0.0
            for q in wl.queries:
                _, rep = eng.read(mech, q)
                wall += rep.wall_seconds
                rows += rep.rows_scanned
            res[mech] = (wall / len(wl) * 1e6, rows / len(wl))
        gain = res["tr"][1] / max(res["hr"][1], 1e-9)
        record(f"fig5c/keys{nk}_tr", res["tr"][0], f"rows={res['tr'][1]:.0f}")
        record(f"fig5c/keys{nk}_hr", res["hr"][0], f"rows={res['hr'][1]:.0f};gain={gain:.2f}x")
        out[nk] = {"tr": res["tr"], "hr": res["hr"], "gain_rows": gain}
    return out


if __name__ == "__main__":
    for nk, r in run().items():
        print(nk, r)
