"""Fig 4 — cost function f(): linear in Row(), slope vs item size / #keys.

Paper claims (C6): (a) cost is ~linear in the candidate-row count Row();
(b) insensitive to the value-column byte width (50→200 B); (c) the slope
grows with the number of clustering keys. We measure actual scan wall
time on this hardware and fit LinearCostFunction per configuration.
"""

from __future__ import annotations

import numpy as np

from repro.core import LinearCostFunction, Query, Range, SortedTable
from repro.core.tpch import generate_simulation
from .common import record, time_fn


def _scan_times(table, schema, widths, rng):
    rows, times = [], []
    dom = schema.max_value("k0") + 1
    for w in widths:
        width = max(1, int(dom * w))
        start = int(rng.integers(0, max(1, dom - width)))
        q = Query(filters={"k0": Range(start, start + width)}, agg="sum", value_col="metric")
        t, res = time_fn(table.execute, q, repeats=3)
        rows.append(res.rows_scanned)
        times.append(t)
    return np.asarray(rows, np.float64), np.asarray(times, np.float64)


def run(n_rows: int = 400_000, seed: int = 0) -> dict:
    rng = np.random.default_rng(seed)
    out = {}

    # (a) item size: 1, 2, 4 extra f64 value columns ≈ 50–200 B rows
    slopes_size = {}
    for n_vals in (1, 2, 4):
        kc, vc, schema = generate_simulation(n_rows, 3, seed=seed)
        for j in range(1, n_vals):
            vc[f"pad{j}"] = rng.uniform(0, 1, n_rows)
        t = SortedTable.from_columns(kc, vc, ("k0", "k1", "k2"), schema)
        rows, times = _scan_times(t, schema, (0.01, 0.05, 0.1, 0.2, 0.4, 0.8), rng)
        f = LinearCostFunction.fit(rows, times)
        slopes_size[n_vals] = (f.slope, f.r2(rows, times))
        record(
            f"fig4a/item_size_{n_vals}x",
            f.slope * 1e6 * 1000,  # us per 1k rows
            f"r2={f.r2(rows, times):.3f}",
        )
    out["item_size"] = slopes_size

    # (b) number of clustering keys 2..6 (slope should grow)
    slopes_keys = {}
    for n_keys in (2, 3, 4, 5, 6):
        kc, vc, schema = generate_simulation(n_rows, n_keys, seed=seed + n_keys)
        layout = tuple(kc)
        t = SortedTable.from_columns(kc, vc, layout, schema)
        rows, times = _scan_times(t, schema, (0.01, 0.05, 0.1, 0.2, 0.4, 0.8), rng)
        f = LinearCostFunction.fit(rows, times)
        slopes_keys[n_keys] = (f.slope, f.r2(rows, times))
        record(
            f"fig4b/n_keys_{n_keys}",
            f.slope * 1e6 * 1000,
            f"r2={f.r2(rows, times):.3f}",
        )
    out["n_keys"] = slopes_keys
    return out


if __name__ == "__main__":
    run()
