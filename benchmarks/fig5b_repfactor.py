"""Fig 5b/5e — latency vs replication factor (simulation dataset).

Paper claim (C2): TR latency is flat in RF; HR latency equals TR at RF=1
and drops sharply for RF ≥ 2.
"""

from __future__ import annotations

import numpy as np

from repro.core import HREngine, random_workload
from repro.core.tpch import generate_simulation
from .common import record


def run(n_rows: int = 300_000, n_keys: int = 3, rfs=(1, 2, 3, 4, 5),
        n_queries: int = 60, seed: int = 0) -> dict:
    kc, vc, schema = generate_simulation(n_rows, n_keys, seed=seed)
    rng = np.random.default_rng(seed + 1)
    wl = random_workload(rng, schema, list(kc), n_queries, value_col="metric")
    out = {}
    for rf in rfs:
        # no result cache: duplicate workload queries must pay the scan,
        # or the paper's latency figures deflate
        eng = HREngine(n_nodes=max(6, rf), result_cache=False)
        eng.create_column_family("tr", kc, vc, replication_factor=rf,
                                 mechanism="TR", workload=wl, schema=schema)
        eng.create_column_family("hr", kc, vc, replication_factor=rf,
                                 mechanism="HR", workload=wl, schema=schema,
                                 hrca_kwargs={"k_max": 2000, "seed": 0})
        res = {}
        for mech in ("tr", "hr"):
            wall = rows = 0.0
            for q in wl.queries:
                _, rep = eng.read(mech, q)
                wall += rep.wall_seconds
                rows += rep.rows_scanned
            res[mech] = (wall / len(wl) * 1e6, rows / len(wl))
        gain = res["tr"][1] / max(res["hr"][1], 1e-9)
        record(f"fig5b/rf{rf}_tr", res["tr"][0], f"rows={res['tr'][1]:.0f}")
        record(f"fig5b/rf{rf}_hr", res["hr"][0], f"rows={res['hr'][1]:.0f};gain={gain:.2f}x")
        out[rf] = {"tr": res["tr"], "hr": res["hr"], "gain_rows": gain}
    return out


if __name__ == "__main__":
    for rf, r in run().items():
        print(rf, r)
