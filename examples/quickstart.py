"""Quickstart: heterogeneous replicas in 60 seconds.

Builds a 3-replica column family over a simulated multi-dimensional
dataset, lets HRCA pick the replica layouts for a query workload, and
compares rows-scanned / latency against the best single ("traditional")
layout an expert could pick. Run:

    PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.core import HREngine, random_workload
from repro.core.tpch import generate_simulation


def main() -> None:
    print("== Heterogeneous Replica quickstart ==")
    kc, vc, schema = generate_simulation(n_rows=200_000, n_keys=4, seed=0)
    rng = np.random.default_rng(1)
    workload = random_workload(rng, schema, list(kc), n_queries=40, value_col="metric")

    engine = HREngine(n_nodes=6)
    engine.create_column_family(
        "tr", kc, vc, replication_factor=3, mechanism="TR",
        workload=workload, schema=schema,
    )
    cf = engine.create_column_family(
        "hr", kc, vc, replication_factor=3, mechanism="HR",
        workload=workload, schema=schema, hrca_kwargs={"k_max": 2000, "seed": 0},
    )
    print("TR layout  (all replicas):", engine.layouts("tr")[0])
    print("HR layouts (per replica): ", *engine.layouts("hr"))
    print(f"HRCA: cost {cf.hrca_result.initial_cost:.0f} → {cf.hrca_result.cost:.0f} "
          f"in {cf.hrca_result.wall_seconds:.2f}s")

    totals = {"tr": [0.0, 0], "hr": [0.0, 0]}
    for q in workload.queries:
        for mech in ("tr", "hr"):
            res, rep = engine.read(mech, q)
            totals[mech][0] += rep.wall_seconds
            totals[mech][1] += rep.rows_scanned
    n = len(workload)
    print(f"\n{'':14s}{'avg latency':>14s}{'avg rows scanned':>18s}")
    for mech in ("tr", "hr"):
        print(f"{mech.upper():14s}{totals[mech][0]/n*1e6:>11.0f} us{totals[mech][1]/n:>18.0f}")
    print(f"\nHR gain: {totals['tr'][1]/max(totals['hr'][1],1):.1f}x fewer rows, "
          f"{totals['tr'][0]/max(totals['hr'][0],1e-12):.1f}x faster")

    # recovery: same dataset, different serialization
    victim = cf.replicas[0].node_id
    engine.fail_node(victim)
    secs = engine.recover_node(victim)
    print(f"node {victim} failed and recovered (replica re-sorted) in {secs*1e3:.0f} ms")


if __name__ == "__main__":
    main()
