"""Batched serving: prefill a prompt batch, decode with a KV cache.

Uses the smoke-size StarCoder2 config on CPU; under a TPU mesh the same
entry point runs the sequence-parallel decode path (seq-sharded KV with
cross-chip flash-decoding). Run:

    PYTHONPATH=src python examples/serve_batch.py [--arch hymba-1.5b]
"""

import argparse

from repro.configs.registry import ARCHS, get_smoke
from repro.launch.serve import serve_batch


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="starcoder2-3b", choices=ARCHS)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=24)
    args = ap.parse_args()

    cfg = get_smoke(args.arch)
    print(f"serving {cfg.name}: batch={args.batch} prompt={args.prompt_len} "
          f"gen={args.gen}")
    out = serve_batch(cfg, batch_size=args.batch, prompt_len=args.prompt_len,
                      gen_tokens=args.gen)
    print(f"prefill: {out['prefill_s']*1e3:.1f} ms")
    print(f"decode:  {out['decode_tok_s']:.1f} tok/s "
          f"({out['decode_s']*1e3:.1f} ms for {args.gen} steps)")
    print(f"sample continuation (greedy): {out['tokens'][0].tolist()}")


if __name__ == "__main__":
    main()
