"""Batched serving: model decode batches AND batched HR reads.

Default mode prefills a prompt batch and decodes with a KV cache using
the smoke-size StarCoder2 config on CPU; under a TPU mesh the same entry
point runs the sequence-parallel decode path (seq-sharded KV with
cross-chip flash-decoding). Run:

    PYTHONPATH=src python examples/serve_batch.py [--arch hymba-1.5b]

``--hr`` serves a batch of TPC-H-style queries through the HR engine's
batched read path instead: one ``read_many`` call ranks replicas for
the whole batch (vectorized cost model), groups queries per chosen
replica, and answers each group with a single vectorized slab scan —
compare its queries/sec against the sequential ``read`` loop:

    PYTHONPATH=src python examples/serve_batch.py --hr --batch 64

``--frontdoor`` goes one layer up: an *open-loop* Poisson arrival
stream (requests carry deadlines, priorities, and mixed consistency)
is pushed through the serving front door, which coalesces arrivals
into dynamic ``read_many`` batches and sheds/degrades under pressure.
Prints client-observed p50/p99 (queue wait included) and the refusal
breakdown against the closed-loop ``read_many`` capacity:

    PYTHONPATH=src python examples/serve_batch.py --frontdoor --load 2

``--views`` contrasts the materialized per-slab aggregate views against
the fused full-scan engine on the same wide-slab aggregate batch: two
device-resident twins of the orders table (one with views, one
without) answer an identical batch of range-sum/count queries, the
answers are asserted bit-identical, and the traced pass prints each
engine's per-stage wall breakdown — the views engine's time lands in
``view.serve`` (stored block partials + boundary rescans) where the
fused engine's lands in the full-table scan stages:

    PYTHONPATH=src python examples/serve_batch.py --views --batch 64

``--trace`` attaches a :class:`repro.obs.Tracer` to the front door:
every request grows a ``frontdoor.request`` span tree (admission →
queue → service, with the engine's plan/scan/digest subtree below),
and the demo prints the per-stage wall breakdown plus the slowest
request's full tree — where an overloaded request's time actually
went. ``--trace-out out.jsonl`` additionally dumps the K slowest
trees as JSON-lines for the offline report CLI:

    PYTHONPATH=src python examples/serve_batch.py --frontdoor --trace \\
        --trace-out /tmp/serve.jsonl
    PYTHONPATH=src python -m repro.obs /tmp/serve.jsonl
"""

import argparse
import itertools
import time


def run_model(args) -> None:
    from repro.configs.registry import ARCHS, get_smoke
    from repro.launch.serve import serve_batch

    if args.arch not in ARCHS:
        raise SystemExit(
            f"unknown --arch {args.arch!r}; choices: {', '.join(sorted(ARCHS))}"
        )
    cfg = get_smoke(args.arch)
    print(f"serving {cfg.name}: batch={args.batch} prompt={args.prompt_len} "
          f"gen={args.gen}")
    out = serve_batch(cfg, batch_size=args.batch, prompt_len=args.prompt_len,
                      gen_tokens=args.gen)
    print(f"prefill: {out['prefill_s']*1e3:.1f} ms")
    print(f"decode:  {out['decode_tok_s']:.1f} tok/s "
          f"({out['decode_s']*1e3:.1f} ms for {args.gen} steps)")
    print(f"sample continuation (greedy): {out['tokens'][0].tolist()}")


def run_hr(args) -> None:
    from repro.core import HREngine
    from repro.core.tpch import generate_orders, orders_schema, q1_q2_workload

    n_rows = args.rows
    print(f"HR batched read demo: {n_rows} orders rows, batch={args.batch}")
    kc, vc = generate_orders(1.0, seed=0, rows_per_sf=n_rows)
    wl = q1_q2_workload(args.batch, seed=1, n_rows=n_rows)
    # no result cache: the demo times the scheduling+scan paths, and the
    # sequential loop would otherwise pre-warm the batch's cache entries
    eng = HREngine(n_nodes=6, result_cache=False)
    eng.create_column_family(
        "orders", kc, vc, replication_factor=3, mechanism="HR", workload=wl,
        schema=orders_schema(), hrca_kwargs={"k_max": 2500, "seed": 0},
    )
    print(f"replica layouts: {[list(a) for a in eng.layouts('orders')]}")

    cf = eng.column_families["orders"]
    cf.rr_counter = itertools.count()  # same tie-break draws for both paths
    t0 = time.perf_counter()
    seq = [eng.read("orders", q) for q in wl.queries]
    t_seq = time.perf_counter() - t0
    cf.rr_counter = itertools.count()
    t0 = time.perf_counter()
    bat = eng.read_many("orders", wl.queries)
    t_bat = time.perf_counter() - t0

    assert all(rb.value == rs.value for (rs, _), (rb, _) in zip(seq, bat))
    total = sum(r.value for r, _ in bat)
    per_replica: dict[int, int] = {}
    for _, rep in bat:
        per_replica[rep.replica_id] = per_replica.get(rep.replica_id, 0) + 1
    print(f"sequential: {args.batch / t_seq:,.0f} q/s ({t_seq*1e3:.1f} ms)")
    print(f"read_many:  {args.batch / t_bat:,.0f} q/s ({t_bat*1e3:.1f} ms) "
          f"— {t_seq / t_bat:.1f}x")
    print(f"routing: {per_replica} (queries per replica), Σvalue={total:,.0f}")


def run_views(args) -> None:
    import numpy as np

    from repro.core import HREngine, Query, Range
    from repro.core.tpch import generate_orders, n_custkey, orders_schema
    from repro.obs import Tracer, stage_totals

    n_rows = args.rows
    print(f"materialized-view demo: {n_rows} orders rows, batch={args.batch}")
    kc, vc = generate_orders(1.0, seed=0, rows_per_sf=n_rows)
    # explicit rotated layouts so replica 0 leads with custkey: the
    # wide-slab custkey ranges below are view-eligible there, and the
    # planner's capped view cost routes them to it
    layouts = [
        ("custkey", "orderdate", "clerk"),
        ("orderdate", "clerk", "custkey"),
        ("clerk", "custkey", "orderdate"),
    ]

    def build(views: bool) -> HREngine:
        eng = HREngine(n_nodes=6, result_cache=False)
        eng.create_column_family(
            "orders", kc, vc, replication_factor=3, layouts=layouts,
            schema=orders_schema(), device_resident=True, views=views,
        )
        return eng

    ev, ef = build(True), build(False)

    # wide-slab eligible aggregates: each range covers most of custkey,
    # so the fused engine streams most of the table per query while the
    # view path folds stored block partials + at most two boundary blocks
    rng = np.random.default_rng(2)
    nck = n_custkey(n_rows)
    queries = [
        Query(
            filters={"custkey": Range(int(rng.integers(0, nck // 4)),
                                      int(rng.integers(nck // 2, nck + 1)))},
            agg="sum" if i % 2 == 0 else "count",
            value_col="totalprice",
        )
        for i in range(args.batch)
    ]

    # warm-up pass doubles as the correctness bar: view-routed answers
    # must be bit-identical to the full-scan engine's
    rv = ev.read_many("orders", queries)
    rf = ef.read_many("orders", queries)
    assert all(a.value == b.value for (a, _), (b, _) in zip(rv, rf))
    print(f"bit-identity: {args.batch}/{args.batch} answers match the "
          f"full-scan engine exactly")

    t0 = time.perf_counter()
    ev.read_many("orders", queries)
    t_vw = time.perf_counter() - t0
    t0 = time.perf_counter()
    ef.read_many("orders", queries)
    t_fu = time.perf_counter() - t0
    print(f"full scan:  {args.batch / t_fu:,.0f} q/s ({t_fu*1e3:.1f} ms)")
    print(f"views:      {args.batch / t_vw:,.0f} q/s ({t_vw*1e3:.1f} ms) "
          f"— {t_fu / t_vw:.1f}x")

    # traced pass: the stage-total tables show WHERE each engine spends
    # the batch — view.serve on the views engine vs the full-table scan
    # stages (engine.scan / kernel launches) on the fused one
    for label, eng in (("views engine", ev), ("full-scan engine", ef)):
        tracer = Tracer()
        root = tracer.root("demo.read_many")
        eng.read_many("orders", queries, trace=root)
        root.end()
        print(f"\nper-stage wall breakdown ({label}):")
        for name, row in stage_totals(tracer.roots).items():
            print(f"  {name:<22} n={row['count']:>5}  "
                  f"total={row['total'] * 1e3:>10,.2f} ms")
    s = ev.stats
    print(f"\nview counters: view_hits={s['view_hits']} "
          f"view_boundary_rows={s['view_boundary_rows']} "
          f"view_rebuilds={s['view_rebuilds']}")


def run_frontdoor(args) -> None:
    import numpy as np

    from repro.core import HREngine, QUORUM
    from repro.core.tpch import generate_orders, orders_schema, q1_q2_workload
    from repro.serving.frontdoor import FrontDoor, Request

    n_rows = args.rows
    print(f"front-door serving demo: {n_rows} orders rows, "
          f"{args.requests} requests at {args.load:g}x capacity")
    kc, vc = generate_orders(1.0, seed=0, rows_per_sf=n_rows)
    wl = q1_q2_workload(args.requests, seed=1, n_rows=n_rows)
    eng = HREngine(n_nodes=6, result_cache=False)
    eng.create_column_family(
        "orders", kc, vc, replication_factor=3, mechanism="HR", workload=wl,
        schema=orders_schema(), hrca_kwargs={"k_max": 2500, "seed": 0},
    )
    queries = list(wl.queries)

    # closed-loop capacity: back-to-back full read_many batches — the
    # baseline the open-loop offered load is expressed against
    t0 = time.perf_counter()
    for i in range(0, len(queries), args.batch):
        eng.read_many("orders", queries[i : i + args.batch])
    t_closed = time.perf_counter() - t0
    closed_qps = len(queries) / t_closed
    print(f"closed-loop read_many: {closed_qps:,.0f} q/s "
          f"({t_closed * 1e3:.1f} ms)")

    rng = np.random.default_rng(2)
    rate = args.load * closed_qps
    arrivals = np.cumsum(rng.exponential(1.0 / rate, len(queries)))
    reqs = [
        Request(
            "orders", q, arrival_s=float(arrivals[i]),
            deadline_s=args.deadline * 1e-3,
            priority=int(rng.integers(0, 3)),
            consistency=QUORUM if rng.random() < 0.25 else "ONE",
        )
        for i, q in enumerate(queries)
    ]
    tracer = None
    if args.trace or args.trace_out:
        from repro.obs import Tracer

        tracer = Tracer()
    fd = FrontDoor(
        eng, max_batch=args.batch, max_wait=2e-3, max_queue=256,
        tracer=tracer,
    )
    resps = fd.serve(reqs)
    s = fd.stats

    ok = [r for r in resps if r.ok]
    if ok:
        lat = np.asarray([r.latency_s for r in ok])
        p50, p99 = np.percentile(lat, 50) * 1e3, np.percentile(lat, 99) * 1e3
        print(f"open-loop through front door: {len(ok)}/{len(reqs)} ok, "
              f"p50={p50:.2f} ms p99={p99:.2f} ms (queue wait included)")
    else:
        print(f"open-loop through front door: 0/{len(reqs)} ok")
    print(f"refusals: shed_overload={s['shed_overload']} "
          f"shed_deadline={s['shed_deadline']} "
          f"rejected_queue_full={s['rejected_queue_full']}")
    print(f"degradation: consistency_degraded={s['consistency_degraded']} "
          f"hedged_batches={s['hedged_batches']} "
          f"degrade_recoveries={s['degrade_recoveries']}")
    print(f"batches={s['batches']} max_queue_depth={s['max_queue_depth']}")

    if tracer is not None:
        from repro.obs import dump_jsonl, format_tree, stage_totals

        print("\nper-stage wall breakdown (all request trees):")
        for name, row in stage_totals(tracer.roots).items():
            print(f"  {name:<22} n={row['count']:>5}  "
                  f"total={row['total'] * 1e3:>10,.2f} ms")
        slowest = fd.slow_log.entries()
        if slowest:
            lat, tree = slowest[0]
            print(f"\nslowest request ({lat * 1e3:.2f} ms):")
            print(format_tree(tree, unit="ms"))
        if args.trace_out:
            n = dump_jsonl(slowest, args.trace_out)
            print(f"\nwrote {n} slowest span trees to {args.trace_out} "
                  f"(render with: python -m repro.obs {args.trace_out})")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--hr", action="store_true",
                    help="serve a query batch via HREngine.read_many")
    ap.add_argument("--frontdoor", action="store_true",
                    help="open-loop arrivals through the serving front door")
    ap.add_argument("--views", action="store_true",
                    help="materialized per-slab aggregate views vs the "
                         "fused full scan, with traced stage breakdowns")
    ap.add_argument("--arch", default="starcoder2-3b")
    ap.add_argument("--batch", type=int, default=None,
                    help="default: 4 (model mode), 64 (--hr/--frontdoor)")
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=24)
    ap.add_argument("--rows", type=int, default=120_000,
                    help="orders rows for --hr/--frontdoor mode")
    ap.add_argument("--requests", type=int, default=400,
                    help="open-loop request count (--frontdoor)")
    ap.add_argument("--load", type=float, default=2.0,
                    help="offered load as a multiple of closed-loop capacity")
    ap.add_argument("--deadline", type=float, default=50.0,
                    help="per-request deadline in ms (--frontdoor)")
    ap.add_argument("--trace", action="store_true",
                    help="trace every request through the front door and "
                         "print the stage breakdown + slowest tree")
    ap.add_argument("--trace-out", default=None, metavar="OUT.jsonl",
                    help="dump the slowest span trees as JSON-lines "
                         "(implies tracing; render with python -m repro.obs)")
    args = ap.parse_args()
    if args.batch is None:
        args.batch = 64 if (args.hr or args.frontdoor or args.views) else 4
    if args.views:
        run_views(args)
    elif args.frontdoor:
        run_frontdoor(args)
    elif args.hr:
        run_hr(args)
    else:
        run_model(args)


if __name__ == "__main__":
    main()
