"""HR checkpoint-replica routing: restore queries pick the cheapest
manifest serialization (paper §2 applied to checkpoint I/O).

Saves a model checkpoint with 3 replica manifests in different
(stack, layer, kind) orders, then costs three restore patterns — full,
layer-range (warm partial restart), by-kind (optimizer-less eval
restore) — on the best vs worst replica. Run:

    PYTHONPATH=src python examples/checkpoint_routing.py
"""

import tempfile

import jax

from repro.checkpoint.layouts import CheckpointRouter
from repro.checkpoint.manager import save_checkpoint
from repro.configs import get_smoke
from repro.core import Eq, Query, Range
from repro.models import lm


def main() -> None:
    cfg = get_smoke("yi-34b")
    params = lm.init_lm(jax.random.PRNGKey(0), cfg)
    with tempfile.TemporaryDirectory() as d:
        save_checkpoint(d, 100, {"params": params}, n_chunks=8, replicas=3)
        router = CheckpointRouter(d, 100)
        print("replica manifest layouts:", *router.layouts, sep="\n  ")

        cases = {
            "full restore": Query(filters={}),
            "layer range [0,2)": Query(filters={"layer": Range(0, 2)}),
            "single kind": Query(filters={"kind_id": Eq(0)}),
            "kind 0 of layer 0": Query(filters={"layer": Eq(0), "kind_id": Eq(0)}),
        }
        print(f"\n{'restore query':>22s} {'best span':>10s} {'worst span':>11s} "
              f"{'needed':>7s} {'replica':>8s}")
        for name, q in cases.items():
            best = router.plan(q)
            worst = router.worst_plan(q)
            print(f"{name:>22s} {best.files_span:>10d} {worst.files_span:>11d} "
                  f"{best.files_needed:>7d} {best.replica:>8d}")
        print("\nspan = contiguous files streamed; the Request Scheduler picks")
        print("the replica whose serialization makes the query's span minimal.")


if __name__ == "__main__":
    main()
