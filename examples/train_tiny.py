"""End-to-end training driver: ~100M-param model, few hundred steps, CPU.

Exercises the full substrate: HR-routed data pipeline (curriculum queries
scheduled to the cheapest replica), AdamW + cosine schedule, async
checkpointing with HR-layout replica manifests, and an injected node
failure at step 120 (data replica rebuilt through HR Recovery; model
state restarted from the last checkpoint). Run:

    PYTHONPATH=src python examples/train_tiny.py [--steps 300]
"""

import argparse
import dataclasses

from repro.ft.failures import FailurePlan
from repro.launch.train import TrainLoopConfig, run_training
from repro.models.config import ArchConfig
from repro.training.optimizer import OptConfig


def tiny_100m() -> ArchConfig:
    """~100M params: 12L × 768 (GPT-2-small-class, llama-style blocks)."""
    return ArchConfig(
        name="tiny-100m",
        family="dense",
        n_layers=12,
        d_model=768,
        n_heads=12,
        n_kv_heads=4,
        d_ff=2048,
        vocab_size=32_000,
        attention="gqa",
        act="silu",
        gated_mlp=True,
    )


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt-dir", default="artifacts/train_tiny_ckpt")
    ap.add_argument("--fail-at", type=int, default=120)
    args = ap.parse_args()

    cfg = tiny_100m()
    print(f"model: {cfg.name} {cfg.param_count()/1e6:.0f}M params")
    loop = TrainLoopConfig(
        steps=args.steps,
        batch_size=args.batch,
        seq_len=args.seq,
        ckpt_dir=args.ckpt_dir,
        ckpt_every=50,
        log_every=20,
        opt=OptConfig(lr=3e-4, warmup_steps=30, total_steps=args.steps),
        failure_plan=FailurePlan(fail_at_steps=(args.fail_at,), nodes=(0,))
        if args.fail_at
        else FailurePlan(),
    )
    summary = run_training(cfg, loop)
    print(f"\nfinal loss {summary['final_loss']:.4f} "
          f"(start {summary['losses'][0]:.4f})")
    print(f"data replica layouts: {summary['data_layouts']}")
    print(f"avg rows scanned per curriculum query: {summary['avg_rows_scanned']:.0f}")
    print(f"recoveries survived: {len(summary['recoveries'])}")


if __name__ == "__main__":
    main()
