"""Reproduce the paper's TPC-H experiment (Fig 5a/5d) end to end.

Generates the `orders` table at several scale factors, runs 100 Q1/Q2
instances against TR (expert layout) and HR (HRCA layouts), and prints
the latency/row-scan gains. Paper claim: 1–2 orders of magnitude at
SF 5. Run:

    PYTHONPATH=src:. python examples/tpch_repro.py [--rows-per-sf 150000]
"""

import argparse

from benchmarks.fig5a_datasize import run


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--rows-per-sf", type=int, default=60_000,
                    help="1_500_000 reproduces the paper's SF scaling exactly")
    ap.add_argument("--queries", type=int, default=100)
    args = ap.parse_args()

    print("== TPC-H orders: TR vs HR (paper Fig 5a/5d) ==")
    results = run(rows_per_sf=args.rows_per_sf, n_queries=args.queries)
    print(f"\n{'SF':>3s} {'TRdef rows':>11s} {'TRexp rows':>11s} {'HR rows':>9s} "
          f"{'gain(def)':>10s} {'gain(exp)':>10s}")
    for sf, r in results.items():
        print(f"{sf:>3d} {r['tr_defined_rows']:>11.0f} {r['tr_expert_rows']:>11.1f} "
              f"{r['hr_rows']:>9.1f} {r['gain_rows']:>9.0f}x {r['gain_vs_expert']:>9.1f}x")
    last = results[max(results)]
    print(f"\nexpert TR layout: {last['tr_expert_layout']}")
    print(f"HR layouts: {last['hr_layouts']}")
    print(f"paper claim C1 (1–2 orders of magnitude vs the declared order): "
          f"measured {last['gain_rows']:.0f}x rows")


if __name__ == "__main__":
    main()
