#!/usr/bin/env bash
# Pre-merge gate: tier-1 tests + a toy-scale pass over every registered
# benchmark (catches import/shape breakage in paths the unit tests stub).
#
#   scripts/ci.sh              # full gate
#   scripts/ci.sh -m kernel    # extra pytest args pass through
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH}

python -m pytest -x -q "$@"
python -m benchmarks.run --smoke
