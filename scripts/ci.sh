#!/usr/bin/env bash
# Pre-merge gate: tier-1 tests + a toy-scale pass over every registered
# benchmark (catches import/shape breakage in paths the unit tests stub)
# + the benchmark regression gate (smoke queries/sec vs the committed
# BENCH_batched_read.json smoke_baseline; >30% drop fails — tune with
# BENCH_GATE_TOL on noisy machines).
#
#   scripts/ci.sh              # full gate
#   scripts/ci.sh -m kernel    # extra pytest args pass through
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH}

python -m pytest -x -q "$@"

# seeded chaos smoke: crash/torn-tail/corruption/slow-node schedules
# must leave reads identical to the no-fault oracle (repro/ft/chaos.py).
# The run is traced: --trace dumps one TickClock span tree per QUORUM
# probe and fails on an empty or malformed dump, and the report CLI
# must parse it (exit nonzero on malformed JSON-lines / empty log)
chaos_trace="$(mktemp --suffix=.jsonl)"
overload_trace="$(mktemp --suffix=.jsonl)"
trap 'rm -f "$chaos_trace" "$overload_trace"' EXIT
python -m repro.ft.chaos --seeds 3 --steps 25 --trace "$chaos_trace"
python -m repro.obs "$chaos_trace" --unit ticks --top 1 > /dev/null

# front-door overload smoke: a seeded Poisson burst + slow-drain run
# where every request must answer identically to the oracle or be
# explicitly shed/rejected (the shed-or-exact property); traced the
# same way — the slow-query log must come back non-empty and parseable
python -m repro.ft.chaos --overload --seeds 2 --trace "$overload_trace"
python -m repro.obs "$overload_trace" --top 1 > /dev/null

# materialized-view chaos smoke: the same fault schedule on
# device-resident column families with per-slab aggregate views —
# view-routed answers must stay bit-identical to the no-fault oracle
# and the stored partials must verify after heal (repro/ft/chaos.py)
python -m repro.ft.chaos --views --seeds 2 --steps 14

# views bench smoke on its own first (fast import/shape check for the
# newest section), then the full registered-benchmark smoke pass whose
# JSON feeds the regression gate (views_qps and the gated
# views_over_fused_speedup ratio included — see scripts/bench_gate.py)
python -m benchmarks.run --smoke --only views > /dev/null

smoke_json="$(mktemp)"
trap 'rm -f "$smoke_json" "$chaos_trace" "$overload_trace"' EXIT
python -m benchmarks.run --smoke --json "$smoke_json"
python scripts/bench_gate.py "$smoke_json" BENCH_batched_read.json
