#!/usr/bin/env python
"""Benchmark regression gate (invoked by scripts/ci.sh).

Compares the throughput numbers of a fresh ``benchmarks.run --smoke
--json`` pass against the committed baseline — the ``smoke_baseline``
section of ``BENCH_batched_read.json`` — and fails (exit 1) when any
engine regresses by more than ``--tol`` (default 0.30 per the PR 3
gate; override with ``--tol`` or the ``BENCH_GATE_TOL`` env var, e.g.
on noisy shared machines). Gated sections: batched-read queries/sec,
write-queue committed rows/sec (the durable write path + group
commit), recovery rows/sec (log replay and survivor re-sort), and
partitioned-read queries/sec (scatter-gather over the token ring at
each partition count, plus the ``p{P}_skew_qps`` post-rebalance drain
on the Zipf-skewed vnode ring — imbalance before/after and rows moved
ride along as descriptive, ungated keys), availability
(hinted-handoff heal vs full log replay rows/sec, ONE vs QUORUM
queries/sec — ``hint_speedup`` / ``quorum_over_one`` stay ungated),
and serving (front-door passthrough vs direct ``read_many`` q/s, plus
the open-loop per-load ``*_p99_us`` latencies — the one family gated
LOWER-is-better: a p99 more than 2x ``--tol`` above baseline fails
(tails are noisier than best-of-N throughputs, and the regressions
worth catching inflate them 5-10x); shed/degrade/ok rates stay
descriptive), and views (materialized per-slab aggregates vs the fused
full scan: ``views_qps``/``fused_qps`` plus the one gated *ratio*
family, ``views_over_fused_speedup`` — the tentpole's O(blocks
touched) advantage must not silently erode even if both absolute
throughputs drift together; the older ``hr_speedup``/``tr_speedup``/
``hint_speedup`` ratios remain descriptive as documented).

Besides the baseline comparison, one *absolute* guard runs every time:
the serving benchmark's ``trace_overhead`` (traced vs untraced
front-door passthrough, median within-pair ratio minus one) must stay
under ``--trace-tol`` (default 0.35, env ``BENCH_GATE_TRACE_TOL``) —
an instrumentation change that makes tracing itself expensive (a span
per row, an eager attr render) fails here even on a machine with no
recorded baseline.

    python scripts/bench_gate.py SMOKE.json BENCH_batched_read.json
    python scripts/bench_gate.py SMOKE.json BENCH_batched_read.json --update

``--update`` records the smoke run's numbers as the new baseline
instead of gating (run it on the reference machine after a deliberate
perf change).
"""

from __future__ import annotations

import argparse
import json
import os
import sys


def flatten_qps(d: dict, prefix: str = "") -> dict[str, float]:
    """Flat {'64/hr_batch_qps': v, 'device/16/fused_qps': v, ...} from
    the nested benchmark result; only *_qps / *_rows_per_sec
    (throughputs, higher is better) and *_p99_us (tail latencies,
    lower is better) leaves are gated — ratios, rates and row counts
    are descriptive."""
    out: dict[str, float] = {}
    for k, v in d.items():
        key = f"{prefix}/{k}" if prefix else str(k)
        if isinstance(v, dict):
            out.update(flatten_qps(v, key))
        elif isinstance(v, (int, float)) and (
            str(k).endswith("_qps")
            or str(k).endswith("_rows_per_sec")
            or str(k).endswith("p99_us")
            # the one gated ratio family: the views tentpole's speedup
            # over the fused scan (named so legacy descriptive ratios
            # — hr_speedup, hint_speedup, ... — stay ungated)
            or str(k).endswith("_over_fused_speedup")
        ):
            out[key] = float(v)
    return out


def lower_is_better(key: str) -> bool:
    """Latency keys regress by going UP; throughput keys by going down."""
    return key.endswith("p99_us")


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("smoke_json", help="output of benchmarks.run --smoke --json")
    ap.add_argument("baseline_json", help="committed BENCH_batched_read.json")
    ap.add_argument(
        "--tol",
        type=float,
        default=float(os.environ.get("BENCH_GATE_TOL", 0.30)),
        help="max allowed fractional regression (default 0.30)",
    )
    ap.add_argument(
        "--trace-tol",
        type=float,
        default=float(os.environ.get("BENCH_GATE_TRACE_TOL", 0.35)),
        help="max allowed traced-vs-untraced passthrough overhead "
        "(absolute guard, default 0.35)",
    )
    ap.add_argument(
        "--update", action="store_true",
        help="write the smoke numbers into the baseline instead of gating",
    )
    args = ap.parse_args()

    with open(args.smoke_json) as f:
        smoke = json.load(f)

    # absolute instrumentation-overhead guard (independent of any
    # baseline): recording spans must stay a modest tax on the
    # passthrough path, or the observability layer is lying about
    # being cheap enough to leave on
    trace_overhead = smoke.get("serving", {}).get("trace_overhead")
    if trace_overhead is not None:
        print(
            f"[bench-gate] trace overhead {trace_overhead * 100:+.1f}% "
            f"(limit {args.trace_tol * 100:.0f}%)"
        )
        if trace_overhead > args.trace_tol:
            print(
                "[bench-gate] REGRESSION: tracing costs "
                f"{trace_overhead * 100:.0f}% over the untraced front door "
                f"(> {args.trace_tol * 100:.0f}%)"
            )
            return 1
    # reads AND writes/recovery are gated: *_qps from the batched-read
    # section, *_rows_per_sec from the write-queue drain and the two
    # recovery paths. (thread_overlap_speedup and the copy/resort ratios
    # are descriptive — ratios, not throughputs — and stay ungated.)
    flat: dict[str, float] = {}
    for section in (
        "batched", "write_queue", "recovery", "partitioned", "availability",
        "serving", "views",
    ):
        flat.update(flatten_qps(smoke.get(section, {}), section))
    # parallel_merge measures thread-pool scheduling, which at smoke
    # scale is dominated by pool startup jitter; the sequential drain
    # rows/sec already gates the write path itself
    flat = {k: v for k, v in flat.items() if "parallel_merge" not in k}

    baseline_doc = {}
    if os.path.exists(args.baseline_json):
        with open(args.baseline_json) as f:
            baseline_doc = json.load(f)

    if args.update:
        baseline_doc["smoke_baseline"] = flat
        with open(args.baseline_json, "w") as f:
            json.dump(baseline_doc, f, indent=1)
            f.write("\n")
        print(f"[bench-gate] baseline updated: {len(flat)} throughput keys")
        return 0

    baseline = baseline_doc.get("smoke_baseline")
    if not baseline:
        print(
            "[bench-gate] no smoke_baseline committed in "
            f"{args.baseline_json}; run with --update to record one"
        )
        return 0

    failures, checked, skipped = [], 0, 0
    for key, base in sorted(baseline.items()):
        if key not in flat:
            skipped += 1
            continue
        checked += 1
        if lower_is_better(key):
            # tail latencies get 2x the throughput tolerance: even a
            # min-of-N p99 swings ~1.4x with ambient machine load,
            # while the regressions this gate exists to catch (a broken
            # degradation ladder, unbounded queueing) inflate it 5-10x
            ptol = 2.0 * args.tol
            if flat[key] > base * (1.0 + ptol):
                failures.append(
                    f"  {key}: {flat[key]:,.0f} > baseline {base:,.0f} "
                    f"(+{(flat[key] / base - 1.0) * 100.0:.0f}% > "
                    f"{ptol * 100:.0f}%)"
                )
        elif flat[key] < base * (1.0 - args.tol):
            failures.append(
                f"  {key}: {flat[key]:,.0f} < baseline {base:,.0f} "
                f"(-{(1.0 - flat[key] / base) * 100.0:.0f}% > {args.tol * 100:.0f}%)"
            )
    print(
        f"[bench-gate] {checked} throughput keys checked against baseline "
        f"(tol {args.tol * 100:.0f}%), {skipped} baseline keys absent from this run"
    )
    if failures:
        print("[bench-gate] REGRESSIONS:")
        print("\n".join(failures))
        return 1
    print("[bench-gate] OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
